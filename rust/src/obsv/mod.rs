//! Observability layer: lock-light latency histograms, gauges, and the
//! structured metrics snapshot every serving face renders from.
//!
//! The paper's headline is a *measured* accuracy-vs-speed tradeoff, so the
//! serving stack has to be able to observe its own latency. This module
//! gives it:
//!
//! * [`Histogram`] — fixed log-spaced buckets (powers of two in
//!   microseconds), every slot an `AtomicU64`, so recording on the solve
//!   hot path is three relaxed atomic adds and a 27-entry binary search:
//!   no locks, no allocation. Merge and quantile estimation operate on
//!   [`HistSnapshot`]s (a consistent point-in-time read).
//! * [`HistogramSet`] — a labeled family keyed by
//!   [`JobLabels`] (`SolverKind` × engine × bits) and optionally an
//!   [`Outcome`] (`ok` / `failed` / `cancelled` / `rejected_full` —
//!   the apollographql/router compute-pool taxonomy: keep queue wait,
//!   execution and end-to-end duration separate, and label terminal
//!   duration by outcome).
//! * [`ServiceObsv`] — the registry the coordinator records into:
//!   queue-wait, quantize+pack setup, execution and end-to-end
//!   histograms plus worker-saturation and in-flight gauges.
//! * Prometheus text exposition (`# HELP`/`# TYPE`, `_bucket`/`_sum`/
//!   `_count` series) — served over the wire via `ScrapeReq`/`Scrape`
//!   and `lpcs scrape ADDR`. The outcome counters
//!   (`lpcs_jobs_total{...,outcome=...}`) are rendered from the *same*
//!   snapshot as the end-to-end histogram, so a scrape taken mid-load is
//!   internally consistent: `lpcs_job_e2e_us_count` equals the sum of
//!   the outcome counters for the same label set, always.
//! * [`MetricsSnapshot`] — the structured form of the legacy
//!   `metrics=` text line; the wire server, router and CLI all render
//!   through [`MetricsSnapshot::render_legacy`] instead of
//!   concatenating strings ad hoc (the text form stays byte-compatible
//!   with what parsing consumers already scrape).
//! * [`TraceId`] — the fleet-wide trace id minted at the first submit
//!   face (FNV-1a of the submit bytes mixed with a per-process counter,
//!   no clock) and carried on every wire v4 frame a job's lifecycle
//!   touches; the coordinator attaches it as an exemplar on the
//!   end-to-end histogram so one scrape links a latency bucket to a
//!   concrete, watchable job.
//! * [`parse_exposition`] / [`Histogram::from_cumulative`] — the
//!   federation path: the router parses each backend's text exposition
//!   back into histograms and folds them together with
//!   [`Histogram::merge_from`], so one router scrape shows the fleet.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;

/// Finite histogram bucket upper bounds, in microseconds: powers of two
/// from 1 µs to ~67 s. Values above the last bound land in the implicit
/// `+Inf` overflow slot. Log spacing keeps relative quantile error
/// bounded (≤ 2×) across six decades with a fixed, tiny footprint.
pub const BUCKET_BOUNDS_US: [u64; 27] = [
    1,
    2,
    4,
    8,
    16,
    32,
    64,
    128,
    256,
    512,
    1024,
    2048,
    4096,
    8192,
    16384,
    32768,
    65536,
    131072,
    262144,
    524288,
    1048576,
    2097152,
    4194304,
    8388608,
    16777216,
    33554432,
    67108864,
];

/// Bucket count including the `+Inf` overflow slot.
pub const BUCKETS: usize = BUCKET_BOUNDS_US.len() + 1;

/// FNV-1a over a byte slice — the same cheap content hash the wire layer
/// uses, duplicated privately so this module stays dependency-free.
fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Per-process mint counter for [`TraceId`]: two jobs submitting the
/// same bytes still get distinct ids, with no clock involved.
static TRACE_COUNTER: AtomicU64 = AtomicU64::new(0);

/// The fleet-wide trace id: minted once at the first submit face
/// (client CLI or in-process submit), carried on every wire v4
/// `Submit`/`Submitted`/`Progress`/`Done` frame the job's lifecycle
/// touches, stored in the coordinator's `JobStore`, and attached as an
/// exemplar to the end-to-end latency histogram. Zero means "absent" —
/// a pre-v4 peer submitted the job.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub struct TraceId(pub u64);

impl TraceId {
    /// The absent id (pre-v4 peers, untraced in-process submits).
    pub const NONE: TraceId = TraceId(0);

    /// Mint a fresh id: FNV-1a of the submit bytes mixed with a
    /// per-process counter (golden-ratio stride so consecutive mints
    /// differ in high bits too). Deterministic per process — no
    /// `Date::now` — and never zero, so zero stays reserved for
    /// "absent".
    pub fn mint(submit_bytes: &[u8]) -> TraceId {
        let n = TRACE_COUNTER.fetch_add(1, Ordering::Relaxed).wrapping_add(1);
        let id = fnv64(submit_bytes) ^ n.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        TraceId(if id == 0 { 1 } else { id })
    }

    /// [`TraceId::mint`] over the canonical submit bytes every submit
    /// face uses: observation length, sparsity, and a bounded prefix of
    /// y — cheap whatever the problem size; the mint counter breaks any
    /// remaining ties.
    pub fn mint_submit(y: &[f32], s: usize) -> TraceId {
        let mut bytes = Vec::with_capacity(16 + 4 * y.len().min(64));
        bytes.extend_from_slice(&(y.len() as u64).to_le_bytes());
        bytes.extend_from_slice(&(s as u64).to_le_bytes());
        for v in y.iter().take(64) {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        Self::mint(&bytes)
    }

    /// Whether this id was actually minted (nonzero).
    pub fn is_set(self) -> bool {
        self.0 != 0
    }
}

/// Trace ids render as fixed-width lowercase hex — the form `lpcs
/// watch`/`lpcs trace` print and exemplar labels carry.
impl std::fmt::Display for TraceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

/// A fixed log-spaced-bucket latency histogram with atomic slots.
/// Recording never locks; readers take a [`HistSnapshot`].
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    /// Last exemplar: the trace id (0 = none) and the sample it tagged.
    /// Stored value-then-id so a reader that sees a nonzero id sees a
    /// plausible value; a torn pair across two exemplars is acceptable
    /// for observability (both halves are real recorded samples).
    exemplar_trace: AtomicU64,
    exemplar_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            exemplar_trace: AtomicU64::new(0),
            exemplar_us: AtomicU64::new(0),
        }
    }

    /// Index of the bucket a value falls in: the first bound ≥ `us`, or
    /// the overflow slot.
    pub fn bucket_index(us: u64) -> usize {
        BUCKET_BOUNDS_US.partition_point(|b| *b < us)
    }

    /// Record one latency sample (microseconds). Three relaxed atomic
    /// adds — safe on any thread, including the solve hot path.
    pub fn record(&self, us: u64) {
        self.buckets[Self::bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Tag the series with an exemplar: the concrete (latency, trace id)
    /// pair a scrape can surface next to the bucket the sample fell in.
    /// Latest-wins; a no-op for unset trace ids.
    pub fn record_exemplar(&self, us: u64, trace: TraceId) {
        if trace.is_set() {
            self.exemplar_us.store(us, Ordering::Relaxed);
            self.exemplar_trace.store(trace.0, Ordering::Relaxed);
        }
    }

    /// Fold another histogram's counts into this one (shard merge). If
    /// this histogram carries no exemplar yet, the other's is adopted —
    /// a federated merge keeps at least one trace id per family.
    pub fn merge_from(&self, other: &Histogram) {
        let snap = other.snapshot();
        for (slot, n) in self.buckets.iter().zip(snap.buckets.iter()) {
            if *n > 0 {
                slot.fetch_add(*n, Ordering::Relaxed);
            }
        }
        self.sum_us.fetch_add(snap.sum_us, Ordering::Relaxed);
        self.count.fetch_add(snap.count, Ordering::Relaxed);
        if let Some((trace, us)) = snap.exemplar {
            if self.exemplar_trace.load(Ordering::Relaxed) == 0 {
                self.record_exemplar(us, TraceId(trace));
            }
        }
    }

    /// Point-in-time copy of all slots.
    pub fn snapshot(&self) -> HistSnapshot {
        let trace = self.exemplar_trace.load(Ordering::Relaxed);
        HistSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            count: self.count.load(Ordering::Relaxed),
            sum_us: self.sum_us.load(Ordering::Relaxed),
            exemplar: (trace != 0)
                .then(|| (trace, self.exemplar_us.load(Ordering::Relaxed))),
        }
    }

    /// Rebuild a histogram from a parsed cumulative series — the
    /// federation path: a backend's text exposition, parsed back into
    /// the shape [`Histogram::merge_from`] understands. Returns `None`
    /// when the series does not use this crate's bucket bounds or the
    /// cumulative counts are not monotone, so a foreign or corrupt
    /// exposition can never poison a merge.
    pub fn from_cumulative(p: &ParsedHist) -> Option<Histogram> {
        if p.bounds.len() != BUCKETS || p.cumulative.len() != BUCKETS {
            return None;
        }
        for (b, want) in p.bounds.iter().zip(BUCKET_BOUNDS_US.iter()) {
            if *b != *want as f64 {
                return None;
            }
        }
        if !p.bounds[BUCKETS - 1].is_infinite() {
            return None;
        }
        let h = Histogram::new();
        let mut prev = 0u64;
        for (slot, &cum) in h.buckets.iter().zip(p.cumulative.iter()) {
            if cum < prev {
                return None;
            }
            slot.store(cum - prev, Ordering::Relaxed);
            prev = cum;
        }
        h.count.store(p.count.max(prev), Ordering::Relaxed);
        h.sum_us.store(p.sum_us, Ordering::Relaxed);
        if let Some((trace, us)) = p.exemplar {
            h.record_exemplar(us, TraceId(trace));
        }
        Some(h)
    }
}

/// A consistent read of a [`Histogram`]; quantile math and rendering
/// operate here so concurrent recording can't skew one exposition line
/// against another.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: [u64; BUCKETS],
    pub count: u64,
    pub sum_us: u64,
    /// Last recorded exemplar, as `(trace id, sample µs)`; `None` when
    /// the series has never been tagged.
    pub exemplar: Option<(u64, u64)>,
}

impl HistSnapshot {
    pub fn empty() -> Self {
        Self { buckets: [0; BUCKETS], count: 0, sum_us: 0, exemplar: None }
    }

    /// Total recorded samples. Concurrent recording can leave the
    /// `count` cell one behind the bucket slots (bucket is bumped
    /// first); rendering uses the max so cumulative `_bucket` series
    /// stay monotone through `+Inf` and `_count` can never undercount
    /// the buckets it sits above.
    pub fn total(&self) -> u64 {
        self.count.max(self.buckets.iter().sum())
    }

    /// Estimated `q`-quantile in microseconds (`0.0 ≤ q ≤ 1.0`), linear
    /// interpolation within the winning bucket. `None` when empty. The
    /// estimate is bounded by the bucket: it is never below the bucket's
    /// lower bound nor above its upper bound.
    pub fn quantile_us(&self, q: f64) -> Option<f64> {
        let total = self.total();
        if total == 0 {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        let target = (q * total as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, n) in self.buckets.iter().enumerate() {
            let next = cum + n;
            if next >= target && *n > 0 {
                let lo = (if i == 0 { 0 } else { BUCKET_BOUNDS_US[i - 1] }) as f64;
                let hi = if i < BUCKET_BOUNDS_US.len() {
                    BUCKET_BOUNDS_US[i] as f64
                } else {
                    // Overflow bucket: all we know is "above the last
                    // bound" — report that bound (a lower bound on truth).
                    return Some(*BUCKET_BOUNDS_US.last().unwrap() as f64);
                };
                let frac = (target - cum) as f64 / *n as f64;
                return Some(lo + frac * (hi - lo));
            }
            cum = next;
        }
        Some(*BUCKET_BOUNDS_US.last().unwrap() as f64)
    }

    /// Merge = pointwise sum (equals the histogram of concatenated
    /// sample streams — pinned by a unit test). Keeps this snapshot's
    /// exemplar, falling back to the other's.
    pub fn merged(&self, other: &HistSnapshot) -> HistSnapshot {
        HistSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i] + other.buckets[i]),
            count: self.count + other.count,
            sum_us: self.sum_us + other.sum_us,
            exemplar: self.exemplar.or(other.exemplar),
        }
    }
}

/// The per-job label set every latency series carries:
/// solver name × engine name × operand bit width (32 = full precision).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobLabels {
    pub solver: &'static str,
    pub engine: &'static str,
    pub bits: u8,
}

/// Terminal job outcomes, following the apollographql compute-pool
/// taxonomy (executed-ok / executed-error / abandoned / rejected-full).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Outcome {
    Ok,
    Failed,
    Cancelled,
    RejectedFull,
}

impl Outcome {
    pub fn name(self) -> &'static str {
        match self {
            Outcome::Ok => "ok",
            Outcome::Failed => "failed",
            Outcome::Cancelled => "cancelled",
            Outcome::RejectedFull => "rejected_full",
        }
    }

    pub const ALL: [Outcome; 4] =
        [Outcome::Ok, Outcome::Failed, Outcome::Cancelled, Outcome::RejectedFull];
}

/// A labeled histogram family. Label sets materialize on first record;
/// the map lock guards only the (rare) lookup/insert — the histograms
/// themselves are lock-free to record into. Callers on a hot loop can
/// hold the returned `Arc` and skip the map entirely.
#[derive(Debug, Default)]
pub struct HistogramSet {
    inner: Mutex<HashMap<(JobLabels, Option<Outcome>), std::sync::Arc<Histogram>>>,
}

impl HistogramSet {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get-or-create the histogram for a series.
    pub fn get(
        &self,
        labels: JobLabels,
        outcome: Option<Outcome>,
    ) -> std::sync::Arc<Histogram> {
        self.inner
            .lock()
            .unwrap()
            .entry((labels, outcome))
            .or_insert_with(|| std::sync::Arc::new(Histogram::new()))
            .clone()
    }

    pub fn record(&self, labels: JobLabels, outcome: Option<Outcome>, us: u64) {
        self.get(labels, outcome).record(us);
    }

    /// Snapshot every series, deterministically ordered (by labels then
    /// outcome) so exposition output is stable.
    pub fn snapshot(&self) -> Vec<(JobLabels, Option<Outcome>, HistSnapshot)> {
        let mut out: Vec<_> = self
            .inner
            .lock()
            .unwrap()
            .iter()
            .map(|((l, o), h)| (*l, *o, h.snapshot()))
            .collect();
        out.sort_by_key(|(l, o, _)| (*l, o.map(|o| o.name())));
        out
    }
}

/// An integer gauge (in-flight jobs, busy workers).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// The coordinator's observability registry: four labeled latency
/// histograms plus the saturation gauges. One per [`crate::coordinator::
/// RecoveryService`], shared by every worker and connection handler.
#[derive(Debug, Default)]
pub struct ServiceObsv {
    /// Submit → execution start, per job.
    pub queue_wait: HistogramSet,
    /// Quantize+pack batch setup: solve-call start → first iteration.
    pub setup: HistogramSet,
    /// Execution-start → terminal, per job.
    pub exec: HistogramSet,
    /// Submit → terminal, per job, labeled by [`Outcome`]. The outcome
    /// counters are *this family's* counts — one source of truth.
    pub e2e: HistogramSet,
    /// Jobs admitted and not yet terminal.
    pub inflight: Gauge,
    /// Workers currently executing a batch.
    pub workers_busy: Gauge,
    /// Worker pool size (static after start).
    pub workers_total: Gauge,
}

impl ServiceObsv {
    pub fn new() -> Self {
        Self::default()
    }

    /// A job left the queue and started executing.
    pub fn on_running(&self, labels: JobLabels, wait_us: u64) {
        self.queue_wait.record(labels, None, wait_us);
    }

    /// One batch's quantize+pack setup latency (solve start → first
    /// observed iteration).
    pub fn on_setup(&self, labels: JobLabels, setup_us: u64) {
        self.setup.record(labels, None, setup_us);
    }

    /// A job reached a terminal state. `exec_us` is `None` for jobs that
    /// never executed (admission rejects). A set `trace` additionally
    /// tags the end-to-end series with an exemplar, so a scrape links
    /// the latency bucket to the concrete job `lpcs watch` showed.
    pub fn on_terminal(
        &self,
        labels: JobLabels,
        outcome: Outcome,
        exec_us: Option<u64>,
        e2e_us: u64,
        trace: TraceId,
    ) {
        if let Some(us) = exec_us {
            self.exec.record(labels, None, us);
        }
        let e2e = self.e2e.get(labels, Some(outcome));
        e2e.record(e2e_us);
        e2e.record_exemplar(e2e_us, trace);
        self.inflight.add(-1);
    }

    /// Outcome totals summed from the end-to-end family (the counters a
    /// scrape exposes — consistent with `_count` by construction).
    pub fn outcome_totals(&self) -> Vec<(JobLabels, Outcome, u64)> {
        self.e2e
            .snapshot()
            .into_iter()
            .filter_map(|(l, o, s)| o.map(|o| (l, o, s.total())))
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Structured metrics snapshot (the legacy text line, typed).
// ---------------------------------------------------------------------------

/// Structured form of `ServiceMetrics::snapshot()` — the coordinator's
/// counters at one instant. `queue_depth` is `Some` on the wire face
/// (the legacy wire `Metrics` reply appended ` queue_depth=N`; the
/// renderer keeps that key order byte-compatible).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServiceCounters {
    pub submitted: u64,
    pub rejected: u64,
    pub invalid: u64,
    pub completed: u64,
    pub failed: u64,
    pub cancelled: u64,
    pub batches: u64,
    pub batched_jobs: u64,
    pub solve_us: u64,
    pub modeled_us: u64,
    pub progress_dropped: u64,
    pub disconnects: u64,
    pub pool_contention: u64,
    pub queue_depth: Option<u64>,
}

impl ServiceCounters {
    /// Mean jobs per executed batch. Same expression as the historical
    /// string formatter (`batched_jobs / batches.max(1)`) so the legacy
    /// line is byte-identical in every state, including the torn read
    /// where `batched_jobs` is bumped a beat before `batches`.
    pub fn mean_batch(&self) -> f64 {
        self.batched_jobs as f64 / self.batches.max(1) as f64
    }

    /// The legacy one-line text form. Key order and formatting are
    /// byte-compatible with the pre-structured `snapshot()` string that
    /// parsing consumers scrape — pinned by a unit test below.
    pub fn render_legacy(&self) -> String {
        let mut s = format!(
            "submitted={} rejected={} invalid={} completed={} failed={} cancelled={} \
             batches={} mean_batch={:.2} solve_ms={} modeled_ms={} progress_dropped={} \
             disconnects={} pool_contention={}",
            self.submitted,
            self.rejected,
            self.invalid,
            self.completed,
            self.failed,
            self.cancelled,
            self.batches,
            self.mean_batch(),
            self.solve_us / 1000,
            self.modeled_us / 1000,
            self.progress_dropped,
            self.disconnects,
            self.pool_contention,
        );
        if let Some(depth) = self.queue_depth {
            s.push_str(&format!(" queue_depth={depth}"));
        }
        s
    }
}

/// One backend's slice of the router counters, plus its health-prober
/// view (up/down, last probed queue depth) — structured where the prober
/// previously only flipped atomics nobody could read out.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BackendCounters {
    pub addr: String,
    pub routed: u64,
    pub resumed: u64,
    pub down_events: u64,
    pub up: bool,
    pub queue_depth: u64,
    pub queue_capacity: u64,
}

/// Structured form of `RouterMetrics::snapshot()`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RouterCounters {
    pub routed: u64,
    pub rejected_full: u64,
    pub rejected_down: u64,
    pub resumed: u64,
    pub backend_down: u64,
    pub inflight: u64,
    pub per_backend: Vec<BackendCounters>,
}

impl RouterCounters {
    /// Legacy one-line text form (byte-compatible key order).
    pub fn render_legacy(&self) -> String {
        let mut s = format!(
            "routed={} rejected_full={} rejected_down={} resumed={} backend_down={}",
            self.routed, self.rejected_full, self.rejected_down, self.resumed, self.backend_down,
        );
        for (i, b) in self.per_backend.iter().enumerate() {
            s.push_str(&format!(
                " b{i}[routed={} resumed={} down={}]",
                b.routed, b.resumed, b.down_events
            ));
        }
        s
    }
}

/// The one structured snapshot type every face plumbs instead of ad-hoc
/// strings: wire server and `lpcs serve` carry `Service`, the router and
/// `lpcs route` carry `Router`; both render the legacy text through
/// [`MetricsSnapshot::render_legacy`].
#[derive(Debug, Clone, PartialEq)]
pub enum MetricsSnapshot {
    Service(ServiceCounters),
    Router(RouterCounters),
}

impl MetricsSnapshot {
    pub fn render_legacy(&self) -> String {
        match self {
            MetricsSnapshot::Service(c) => c.render_legacy(),
            MetricsSnapshot::Router(c) => c.render_legacy(),
        }
    }
}

// ---------------------------------------------------------------------------
// Prometheus text exposition.
// ---------------------------------------------------------------------------

/// Escape a label value per the Prometheus text format: backslash,
/// double-quote and newline.
pub fn escape_label(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn fmt_labels(labels: JobLabels, outcome: Option<Outcome>) -> String {
    let mut s = format!(
        "solver=\"{}\",engine=\"{}\",bits=\"{}\"",
        escape_label(labels.solver),
        escape_label(labels.engine),
        labels.bits
    );
    if let Some(o) = outcome {
        s.push_str(&format!(",outcome=\"{}\"", o.name()));
    }
    s
}

/// Render one histogram series — cumulative `_bucket` lines, `_sum`,
/// `_count` — under a pre-formatted label string (no braces, no `le`;
/// may be empty). The snapshot's exemplar, if any, rides on the bucket
/// line covering its sample as an OpenMetrics-style
/// `# {trace_id="…"} value` suffix. Public so the router can render
/// merged backend families and per-hop series under its own labels.
pub fn render_histogram_series(out: &mut String, name: &str, lab: &str, snap: &HistSnapshot) {
    let sep = if lab.is_empty() { "" } else { "," };
    let exemplar_bucket = snap.exemplar.map(|(_, us)| Histogram::bucket_index(us));
    let push_exemplar = |out: &mut String, i: usize| {
        if exemplar_bucket == Some(i) {
            let (trace, us) = snap.exemplar.unwrap();
            out.push_str(&format!(" # {{trace_id=\"{}\"}} {us}", TraceId(trace)));
        }
    };
    let mut cum = 0u64;
    for (i, n) in snap.buckets[..BUCKET_BOUNDS_US.len()].iter().enumerate() {
        cum += n;
        out.push_str(&format!(
            "{name}_bucket{{{lab}{sep}le=\"{}\"}} {cum}",
            BUCKET_BOUNDS_US[i]
        ));
        push_exemplar(out, i);
        out.push('\n');
    }
    let total = snap.total();
    out.push_str(&format!("{name}_bucket{{{lab}{sep}le=\"+Inf\"}} {total}"));
    push_exemplar(out, BUCKET_BOUNDS_US.len());
    out.push('\n');
    if lab.is_empty() {
        out.push_str(&format!("{name}_sum {}\n", snap.sum_us));
        out.push_str(&format!("{name}_count {total}\n"));
    } else {
        out.push_str(&format!("{name}_sum{{{lab}}} {}\n", snap.sum_us));
        out.push_str(&format!("{name}_count{{{lab}}} {total}\n"));
    }
}

/// Render a whole histogram family (`# HELP`/`# TYPE` header plus every
/// series) keyed by arbitrary pre-formatted label strings — the form
/// the router's per-hop families (labeled by backend) use.
pub fn render_labeled_histogram_family(
    out: &mut String,
    name: &str,
    help: &str,
    series: &[(String, HistSnapshot)],
) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
    for (lab, snap) in series {
        render_histogram_series(out, name, lab, snap);
    }
}

fn render_histogram_family(
    out: &mut String,
    name: &str,
    help: &str,
    series: &[(JobLabels, Option<Outcome>, HistSnapshot)],
) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
    for (labels, outcome, snap) in series {
        render_histogram_series(out, name, &fmt_labels(*labels, *outcome), snap);
    }
}

/// Render an unlabeled scalar series with its `# HELP`/`# TYPE` header.
/// Public for the router's federated exposition assembly.
pub fn render_scalar(
    out: &mut String,
    name: &str,
    kind: &str,
    help: &str,
    value: impl std::fmt::Display,
) {
    out.push_str(&format!(
        "# HELP {name} {help}\n# TYPE {name} {kind}\n{name} {value}\n"
    ));
}

impl ServiceObsv {
    /// The full Prometheus text exposition for one service: the four
    /// latency histograms, outcome counters (rendered from the same
    /// end-to-end snapshot — see module docs), saturation gauges, and
    /// the legacy counters as plain counter series.
    pub fn render_prometheus(
        &self,
        counters: &ServiceCounters,
        queue_depth: u64,
        queue_capacity: u64,
    ) -> String {
        let mut out = String::new();
        render_histogram_family(
            &mut out,
            "lpcs_job_queue_wait_us",
            "Time from submit to execution start, microseconds.",
            &self.queue_wait.snapshot(),
        );
        render_histogram_family(
            &mut out,
            "lpcs_job_setup_us",
            "Quantize+pack batch setup: solve start to first iteration, microseconds.",
            &self.setup.snapshot(),
        );
        render_histogram_family(
            &mut out,
            "lpcs_job_exec_us",
            "Per-job execution time, microseconds.",
            &self.exec.snapshot(),
        );
        // ONE snapshot of the e2e family feeds both the histogram series
        // and the outcome counters, so `_count` == sum of outcomes holds
        // at any instant a scrape can observe.
        let e2e = self.e2e.snapshot();
        render_histogram_family(
            &mut out,
            "lpcs_job_e2e_us",
            "End-to-end latency submit to terminal, microseconds, by outcome.",
            &e2e,
        );
        out.push_str(
            "# HELP lpcs_jobs_total Terminal jobs by solver/engine/bits and outcome.\n\
             # TYPE lpcs_jobs_total counter\n",
        );
        for (labels, outcome, snap) in &e2e {
            if let Some(o) = outcome {
                out.push_str(&format!(
                    "lpcs_jobs_total{{{}}} {}\n",
                    fmt_labels(*labels, Some(*o)),
                    snap.total()
                ));
            }
        }
        render_scalar(
            &mut out,
            "lpcs_inflight_jobs",
            "gauge",
            "Jobs admitted and not yet terminal.",
            self.inflight.get(),
        );
        render_scalar(
            &mut out,
            "lpcs_workers_busy",
            "gauge",
            "Workers currently executing a batch.",
            self.workers_busy.get(),
        );
        render_scalar(
            &mut out,
            "lpcs_workers_total",
            "gauge",
            "Worker pool size.",
            self.workers_total.get(),
        );
        render_scalar(&mut out, "lpcs_queue_depth", "gauge", "Jobs waiting in the queue.", queue_depth);
        render_scalar(
            &mut out,
            "lpcs_queue_capacity",
            "gauge",
            "Bounded queue capacity.",
            queue_capacity,
        );
        for (name, help, v) in [
            ("lpcs_jobs_submitted_total", "Jobs accepted at submit.", counters.submitted),
            ("lpcs_jobs_rejected_total", "Jobs rejected by backpressure.", counters.rejected),
            ("lpcs_jobs_invalid_total", "Jobs rejected by validation.", counters.invalid),
            ("lpcs_batches_total", "Executed batches.", counters.batches),
            (
                "lpcs_progress_dropped_total",
                "Progress events shed by slow subscribers.",
                counters.progress_dropped,
            ),
            ("lpcs_disconnects_total", "Clients that died mid-stream.", counters.disconnects),
            (
                "lpcs_pool_contention_total",
                "Parallel-pool lock contention events.",
                counters.pool_contention,
            ),
        ] {
            render_scalar(&mut out, name, "counter", help, v);
        }
        out
    }
}

/// Prometheus exposition for the router face: routing counters plus
/// per-backend health (the prober's structured view).
pub fn render_router_prometheus(c: &RouterCounters) -> String {
    let mut out = String::new();
    for (name, help, v) in [
        ("lpcs_router_routed_total", "Jobs placed on a backend.", c.routed),
        ("lpcs_router_rejected_full_total", "Jobs rejected: saturation.", c.rejected_full),
        ("lpcs_router_rejected_down_total", "Jobs rejected: no live backend.", c.rejected_down),
        ("lpcs_router_resumed_total", "Watch streams resumed after failover.", c.resumed),
        ("lpcs_router_backend_down_total", "Backend down events.", c.backend_down),
    ] {
        render_scalar(&mut out, name, "counter", help, v);
    }
    render_scalar(
        &mut out,
        "lpcs_router_inflight",
        "gauge",
        "Jobs routed and not yet done.",
        c.inflight,
    );
    out.push_str(
        "# HELP lpcs_router_backend_up Backend health as the prober sees it.\n\
         # TYPE lpcs_router_backend_up gauge\n",
    );
    for (i, b) in c.per_backend.iter().enumerate() {
        out.push_str(&format!(
            "lpcs_router_backend_up{{backend=\"{i}\",addr=\"{}\"}} {}\n",
            escape_label(&b.addr),
            u64::from(b.up)
        ));
    }
    out.push_str(
        "# HELP lpcs_router_backend_queue_depth Last probed backend queue depth.\n\
         # TYPE lpcs_router_backend_queue_depth gauge\n",
    );
    for (i, b) in c.per_backend.iter().enumerate() {
        out.push_str(&format!(
            "lpcs_router_backend_queue_depth{{backend=\"{i}\",addr=\"{}\"}} {}\n",
            escape_label(&b.addr),
            b.queue_depth
        ));
    }
    out.push_str(
        "# HELP lpcs_router_backend_routed_total Jobs placed per backend.\n\
         # TYPE lpcs_router_backend_routed_total counter\n",
    );
    for (i, b) in c.per_backend.iter().enumerate() {
        out.push_str(&format!(
            "lpcs_router_backend_routed_total{{backend=\"{i}\",addr=\"{}\"}} {}\n",
            escape_label(&b.addr),
            b.routed
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// Exposition parsing (the federation path).
// ---------------------------------------------------------------------------

/// One histogram series parsed back out of a text exposition: bucket
/// bounds and cumulative counts exactly as printed, in print order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ParsedHist {
    pub bounds: Vec<f64>,
    pub cumulative: Vec<u64>,
    pub sum_us: u64,
    pub count: u64,
    /// `(trace id, sample µs)` from a `# {trace_id="…"} v` bucket suffix.
    pub exemplar: Option<(u64, u64)>,
}

/// A Prometheus text exposition, parsed back into structure. This is
/// how the router federates: each backend's `Scrape` reply is parsed,
/// histogram families are rebuilt via [`Histogram::from_cumulative`]
/// and folded together with [`Histogram::merge_from`], and scalars are
/// re-emitted under a disambiguating `backend` label. `BTreeMap`s keep
/// iteration (and thus the merged exposition) deterministic.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ParsedExposition {
    /// Family name → `# TYPE` kind.
    pub kinds: BTreeMap<String, String>,
    /// Family name → `# HELP` text.
    pub helps: BTreeMap<String, String>,
    /// `(family name, label string without the le label)` → series.
    pub hists: BTreeMap<(String, String), ParsedHist>,
    /// `(series name, label string)` → value, for counters and gauges.
    pub scalars: BTreeMap<(String, String), i64>,
}

/// Split `name{a="b",…}` into the bare name and the brace-free label
/// string (empty when unlabeled).
fn split_series(series: &str) -> (String, String) {
    match series.split_once('{') {
        Some((name, rest)) => {
            (name.to_string(), rest.trim_end_matches('}').to_string())
        }
        None => (series.to_string(), String::new()),
    }
}

/// Parse a Prometheus text exposition as this module renders it (and
/// tolerantly enough for close dialects: unknown comment lines are
/// skipped, label order is preserved verbatim). Errors name the
/// offending line; the router treats a parse failure like a dead
/// backend — a scrape-error counter, never a poisoned merge.
pub fn parse_exposition(text: &str) -> Result<ParsedExposition, String> {
    let mut out = ParsedExposition::default();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let (name, help) =
                rest.split_once(' ').ok_or_else(|| format!("bad HELP line: {line}"))?;
            out.helps.insert(name.to_string(), help.to_string());
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let (name, kind) =
                rest.split_once(' ').ok_or_else(|| format!("bad TYPE line: {line}"))?;
            out.kinds.insert(name.to_string(), kind.to_string());
            continue;
        }
        if line.starts_with('#') {
            continue;
        }
        // Peel an OpenMetrics-style exemplar suffix off bucket lines:
        // `series value # {trace_id="…"} exemplar-value`.
        let (metric, exemplar) = match line.split_once(" # ") {
            Some((m, ex)) => (m, Some(ex)),
            None => (line, None),
        };
        let (series, value) =
            metric.rsplit_once(' ').ok_or_else(|| format!("metric line has no value: {line}"))?;
        let (name, labs) = split_series(series);
        // A histogram member iff the family (name minus the member
        // suffix) was declared `# TYPE … histogram` — scalars whose
        // names merely end in `_count` stay scalars.
        let member = ["_bucket", "_sum", "_count"].iter().find_map(|suf| {
            let fam = name.strip_suffix(suf)?;
            (out.kinds.get(fam).map(String::as_str) == Some("histogram"))
                .then(|| (fam.to_string(), *suf))
        });
        let Some((family, suffix)) = member else {
            let v: i64 =
                value.parse().map_err(|_| format!("bad scalar value: {line}"))?;
            out.scalars.insert((name, labs), v);
            continue;
        };
        let v: u64 = value.parse().map_err(|_| format!("bad histogram value: {line}"))?;
        // Separate the `le` label from the series-identifying labels.
        let mut le = None;
        let mut rest_labs = Vec::new();
        for item in labs.split(',').filter(|s| !s.is_empty()) {
            match item.strip_prefix("le=\"") {
                Some(b) => le = Some(b.trim_end_matches('"').to_string()),
                None => rest_labs.push(item),
            }
        }
        let h = out.hists.entry((family, rest_labs.join(","))).or_default();
        match suffix {
            "_bucket" => {
                let le = le.ok_or_else(|| format!("bucket line without le: {line}"))?;
                let bound = if le == "+Inf" {
                    f64::INFINITY
                } else {
                    le.parse().map_err(|_| format!("bad le bound: {line}"))?
                };
                h.bounds.push(bound);
                h.cumulative.push(v);
                if let Some(ex) = exemplar {
                    let (exlab, exval) = ex
                        .rsplit_once(' ')
                        .ok_or_else(|| format!("bad exemplar: {line}"))?;
                    let hex = exlab
                        .strip_prefix("{trace_id=\"")
                        .and_then(|s| s.strip_suffix("\"}"))
                        .ok_or_else(|| format!("bad exemplar labels: {line}"))?;
                    let trace = u64::from_str_radix(hex, 16)
                        .map_err(|_| format!("bad exemplar trace id: {line}"))?;
                    let us = exval
                        .parse()
                        .map_err(|_| format!("bad exemplar value: {line}"))?;
                    h.exemplar = Some((trace, us));
                }
            }
            "_sum" => h.sum_us = v,
            _ => h.count = v,
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::XorShift128Plus;

    fn labels() -> JobLabels {
        JobLabels { solver: "qniht", engine: "native-quant", bits: 2 }
    }

    #[test]
    fn bucket_bounds_are_strictly_increasing_and_indexing_is_monotone() {
        for w in BUCKET_BOUNDS_US.windows(2) {
            assert!(w[0] < w[1]);
        }
        let mut last = 0;
        for us in [0u64, 1, 2, 3, 100, 1023, 1024, 1025, 1 << 20, u64::MAX] {
            let i = Histogram::bucket_index(us);
            assert!(i >= last || us == 0, "index must be monotone in the value");
            last = i;
            // The chosen bucket actually covers the value.
            if i < BUCKET_BOUNDS_US.len() {
                assert!(us <= BUCKET_BOUNDS_US[i]);
                if i > 0 {
                    assert!(us > BUCKET_BOUNDS_US[i - 1]);
                }
            } else {
                assert!(us > *BUCKET_BOUNDS_US.last().unwrap());
            }
        }
    }

    #[test]
    fn cumulative_bucket_series_is_monotone() {
        let h = Histogram::new();
        let mut rng = XorShift128Plus::new(7);
        for _ in 0..500 {
            h.record(rng.next_u64() % 10_000_000);
        }
        let s = h.snapshot();
        let mut cum = 0u64;
        let mut prev = 0u64;
        for n in s.buckets.iter() {
            cum += n;
            assert!(cum >= prev);
            prev = cum;
        }
        assert_eq!(cum, s.total());
        assert_eq!(s.count, 500);
    }

    #[test]
    fn merge_equals_concatenated_samples() {
        let mut rng = XorShift128Plus::new(42);
        let a: Vec<u64> = (0..200).map(|_| rng.next_u64() % 1_000_000).collect();
        let b: Vec<u64> = (0..300).map(|_| rng.next_u64() % 100_000_000).collect();
        let ha = Histogram::new();
        let hb = Histogram::new();
        let hc = Histogram::new();
        for &v in &a {
            ha.record(v);
            hc.record(v);
        }
        for &v in &b {
            hb.record(v);
            hc.record(v);
        }
        // merge via the atomic path…
        let merged = Histogram::new();
        merged.merge_from(&ha);
        merged.merge_from(&hb);
        assert_eq!(merged.snapshot(), hc.snapshot());
        // …and via the snapshot path.
        assert_eq!(ha.snapshot().merged(&hb.snapshot()), hc.snapshot());
    }

    #[test]
    fn quantile_estimates_are_bucket_bounded_and_monotone() {
        let h = Histogram::new();
        let mut rng = XorShift128Plus::new(3);
        let mut vals: Vec<u64> = (0..1000).map(|_| 10 + rng.next_u64() % 500_000).collect();
        for &v in &vals {
            h.record(v);
        }
        vals.sort_unstable();
        let s = h.snapshot();
        let mut prev = 0.0;
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
            let est = s.quantile_us(q).unwrap();
            assert!(est >= prev, "quantile must be monotone in q");
            prev = est;
            // Bucket-bounded error: the estimate's bucket contains (or
            // neighbors, at bucket edges) the true order statistic.
            let rank = ((q * vals.len() as f64).ceil().max(1.0) as usize).min(vals.len()) - 1;
            let truth = vals[rank];
            let bi = Histogram::bucket_index(truth);
            let lo = if bi == 0 { 0.0 } else { BUCKET_BOUNDS_US[bi - 1] as f64 };
            let hi = BUCKET_BOUNDS_US[bi.min(BUCKET_BOUNDS_US.len() - 1)] as f64;
            assert!(
                est >= lo && est <= hi,
                "q={q}: est {est} outside bucket [{lo},{hi}] of truth {truth}"
            );
        }
        assert!(HistSnapshot::empty().quantile_us(0.5).is_none());
    }

    #[test]
    fn label_escaping_covers_quote_backslash_newline() {
        assert_eq!(escape_label(r#"a"b\c"#), r#"a\"b\\c"#);
        assert_eq!(escape_label("x\ny"), "x\\ny");
        assert_eq!(escape_label("plain"), "plain");
    }

    #[test]
    fn exposition_format_is_exact_for_a_tiny_family() {
        let obsv = ServiceObsv::new();
        obsv.inflight.add(3);
        obsv.workers_total.set(2);
        obsv.on_terminal(labels(), Outcome::Ok, Some(3), 5, TraceId::NONE);
        let text = obsv.render_prometheus(&ServiceCounters::default(), 1, 256);
        assert!(text.contains("# TYPE lpcs_job_e2e_us histogram\n"));
        assert!(text.contains(
            "lpcs_job_e2e_us_bucket{solver=\"qniht\",engine=\"native-quant\",bits=\"2\",\
             outcome=\"ok\",le=\"8\"} 1\n"
        ));
        assert!(text.contains(
            "lpcs_job_e2e_us_bucket{solver=\"qniht\",engine=\"native-quant\",bits=\"2\",\
             outcome=\"ok\",le=\"4\"} 0\n"
        ));
        assert!(text.contains(
            "lpcs_job_e2e_us_bucket{solver=\"qniht\",engine=\"native-quant\",bits=\"2\",\
             outcome=\"ok\",le=\"+Inf\"} 1\n"
        ));
        assert!(text.contains(
            "lpcs_job_e2e_us_sum{solver=\"qniht\",engine=\"native-quant\",bits=\"2\",\
             outcome=\"ok\"} 5\n"
        ));
        assert!(text.contains(
            "lpcs_job_e2e_us_count{solver=\"qniht\",engine=\"native-quant\",bits=\"2\",\
             outcome=\"ok\"} 1\n"
        ));
        assert!(text.contains(
            "lpcs_jobs_total{solver=\"qniht\",engine=\"native-quant\",bits=\"2\",\
             outcome=\"ok\"} 1\n"
        ));
        assert!(text.contains("lpcs_inflight_jobs 2\n")); // 3 admitted − 1 terminal
        assert!(text.contains("lpcs_workers_total 2\n"));
        assert!(text.contains("lpcs_queue_depth 1\n"));
        assert!(text.contains("lpcs_queue_capacity 256\n"));
        // exec was recorded too (no outcome label on that family).
        assert!(text.contains(
            "lpcs_job_exec_us_count{solver=\"qniht\",engine=\"native-quant\",bits=\"2\"} 1\n"
        ));
    }

    /// A minimal exposition parser: `name{labels} value` → map. Enough
    /// to prove the text round-trips (series naming + label order).
    /// Exemplar suffixes are stripped — they ride after the value.
    fn parse_back(text: &str) -> HashMap<String, u64> {
        let mut out = HashMap::new();
        for line in text.lines() {
            if line.starts_with('#') || line.is_empty() {
                continue;
            }
            let line = line.split(" # ").next().unwrap();
            let (series, value) = line.rsplit_once(' ').expect("metric line has a value");
            if let Ok(v) = value.parse::<u64>() {
                out.insert(series.to_string(), v);
            } else {
                // gauges can be negative; store wrapped for presence checks
                let v: i64 = value.parse().expect("metric value parses as a number");
                out.insert(series.to_string(), v as u64);
            }
        }
        out
    }

    #[test]
    fn exposition_parses_back_consistently() {
        let obsv = ServiceObsv::new();
        let l2 = labels();
        let l8 = JobLabels { solver: "niht", engine: "native-dense", bits: 32 };
        for us in [2u64, 9, 70, 1500] {
            obsv.inflight.add(1);
            obsv.on_terminal(l2, Outcome::Ok, Some(us), us + 1, TraceId::mint(b"t"));
        }
        obsv.inflight.add(1);
        obsv.on_terminal(l2, Outcome::Failed, Some(11), 12, TraceId::NONE);
        obsv.inflight.add(1);
        obsv.on_terminal(l8, Outcome::Cancelled, None, 40, TraceId::NONE);
        let parsed =
            parse_back(&obsv.render_prometheus(&ServiceCounters::default(), 0, 16));
        // _count == sum of outcome counters, per label set.
        let c2: u64 = [
            "lpcs_jobs_total{solver=\"qniht\",engine=\"native-quant\",bits=\"2\",outcome=\"ok\"}",
            "lpcs_jobs_total{solver=\"qniht\",engine=\"native-quant\",bits=\"2\",outcome=\"failed\"}",
        ]
        .iter()
        .map(|k| parsed.get(*k).copied().unwrap_or(0))
        .sum();
        let e2e2: u64 = [
            "lpcs_job_e2e_us_count{solver=\"qniht\",engine=\"native-quant\",bits=\"2\",outcome=\"ok\"}",
            "lpcs_job_e2e_us_count{solver=\"qniht\",engine=\"native-quant\",bits=\"2\",outcome=\"failed\"}",
        ]
        .iter()
        .map(|k| parsed.get(*k).copied().unwrap_or(0))
        .sum();
        assert_eq!(c2, 5);
        assert_eq!(e2e2, 5);
        assert_eq!(
            parsed["lpcs_jobs_total{solver=\"niht\",engine=\"native-dense\",bits=\"32\",outcome=\"cancelled\"}"],
            1
        );
        // +Inf bucket equals _count for every series that has one.
        for (k, v) in &parsed {
            if let Some(prefix) = k.strip_suffix(",le=\"+Inf\"}") {
                let count_key = format!(
                    "{}}}",
                    prefix.replacen("_bucket{", "_count{", 1)
                );
                assert_eq!(parsed[&count_key], *v, "+Inf bucket != _count for {k}");
            }
        }
        assert_eq!(parsed["lpcs_inflight_jobs"], 0); // 6 admitted − 6 terminal
    }

    #[test]
    fn legacy_service_text_is_byte_compatible() {
        let c = ServiceCounters {
            submitted: 10,
            rejected: 1,
            invalid: 2,
            completed: 7,
            failed: 1,
            cancelled: 1,
            batches: 4,
            batched_jobs: 9,
            solve_us: 123_456,
            modeled_us: 42_000,
            progress_dropped: 3,
            disconnects: 1,
            pool_contention: 5,
            queue_depth: None,
        };
        assert_eq!(
            c.render_legacy(),
            "submitted=10 rejected=1 invalid=2 completed=7 failed=1 cancelled=1 \
             batches=4 mean_batch=2.25 solve_ms=123 modeled_ms=42 progress_dropped=3 \
             disconnects=1 pool_contention=5"
        );
        let wire = ServiceCounters { queue_depth: Some(6), ..c };
        assert!(wire.render_legacy().ends_with(" pool_contention=5 queue_depth=6"));
        // Zero batches: mean is 0.00, not NaN.
        let empty = ServiceCounters::default();
        assert!(empty.render_legacy().contains("mean_batch=0.00"));
    }

    #[test]
    fn legacy_router_text_is_byte_compatible() {
        let c = RouterCounters {
            routed: 5,
            rejected_full: 1,
            rejected_down: 0,
            resumed: 2,
            backend_down: 1,
            inflight: 3,
            per_backend: vec![
                BackendCounters { routed: 3, resumed: 2, down_events: 1, ..Default::default() },
                BackendCounters { routed: 2, ..Default::default() },
            ],
        };
        assert_eq!(
            MetricsSnapshot::Router(c).render_legacy(),
            "routed=5 rejected_full=1 rejected_down=0 resumed=2 backend_down=1 \
             b0[routed=3 resumed=2 down=1] b1[routed=2 resumed=0 down=0]"
        );
    }

    #[test]
    fn router_prometheus_renders_backend_series() {
        let c = RouterCounters {
            routed: 5,
            per_backend: vec![BackendCounters {
                addr: "127.0.0.1:7070".into(),
                routed: 5,
                up: true,
                queue_depth: 2,
                ..Default::default()
            }],
            ..Default::default()
        };
        let text = render_router_prometheus(&c);
        assert!(text.contains("lpcs_router_routed_total 5\n"));
        assert!(text
            .contains("lpcs_router_backend_up{backend=\"0\",addr=\"127.0.0.1:7070\"} 1\n"));
        assert!(text.contains(
            "lpcs_router_backend_queue_depth{backend=\"0\",addr=\"127.0.0.1:7070\"} 2\n"
        ));
    }

    #[test]
    fn trace_ids_are_nonzero_distinct_and_render_as_fixed_hex() {
        let a = TraceId::mint(b"same bytes");
        let b = TraceId::mint(b"same bytes");
        assert!(a.is_set() && b.is_set());
        assert_ne!(a, b, "the process counter must separate identical submits");
        assert!(!TraceId::NONE.is_set());
        let hex = a.to_string();
        assert_eq!(hex.len(), 16);
        assert!(hex.chars().all(|c| c.is_ascii_hexdigit()));
        assert_eq!(TraceId(0xabc).to_string(), "0000000000000abc");
    }

    #[test]
    fn exemplar_rides_the_covering_bucket_line_and_survives_merges() {
        let h = Histogram::new();
        h.record(3);
        h.record_exemplar(3, TraceId(0xabc));
        let snap = h.snapshot();
        assert_eq!(snap.exemplar, Some((0xabc, 3)));
        let mut text = String::new();
        render_histogram_series(&mut text, "demo_us", "backend=\"0\"", &snap);
        assert!(text
            .contains("demo_us_bucket{backend=\"0\",le=\"4\"} 1 # {trace_id=\"0000000000000abc\"} 3\n"));
        // Unset trace ids never tag.
        h.record_exemplar(9, TraceId::NONE);
        assert_eq!(h.snapshot().exemplar, Some((0xabc, 3)));
        // A merge into an untagged histogram adopts the exemplar…
        let m = Histogram::new();
        m.merge_from(&h);
        assert_eq!(m.snapshot().exemplar, Some((0xabc, 3)));
        // …but never overwrites one that is already set.
        m.record_exemplar(7, TraceId(0xdef));
        m.merge_from(&h);
        assert_eq!(m.snapshot().exemplar, Some((0xdef, 7)));
    }

    #[test]
    fn parse_exposition_round_trips_the_service_render() {
        let obsv = ServiceObsv::new();
        obsv.inflight.add(2);
        obsv.workers_total.set(3);
        obsv.on_terminal(labels(), Outcome::Ok, Some(3), 5, TraceId(0x1f));
        obsv.on_terminal(labels(), Outcome::Failed, Some(40), 90, TraceId::NONE);
        let text = obsv.render_prometheus(&ServiceCounters::default(), 1, 64);
        let parsed = parse_exposition(&text).expect("our own render parses");
        assert_eq!(parsed.kinds["lpcs_job_e2e_us"], "histogram");
        let lab = "solver=\"qniht\",engine=\"native-quant\",bits=\"2\",outcome=\"ok\"";
        let h = &parsed.hists[&("lpcs_job_e2e_us".to_string(), lab.to_string())];
        assert_eq!(h.bounds.len(), BUCKETS);
        assert_eq!(h.count, 1);
        assert_eq!(h.sum_us, 5);
        assert_eq!(h.exemplar, Some((0x1f, 5)));
        assert_eq!(h.cumulative[BUCKETS - 1], 1);
        // Scalars land keyed by (name, labels) with the gauge values.
        assert_eq!(parsed.scalars[&("lpcs_inflight_jobs".to_string(), String::new())], 0);
        assert_eq!(parsed.scalars[&("lpcs_workers_total".to_string(), String::new())], 3);
        assert_eq!(
            parsed.scalars[&("lpcs_jobs_total".to_string(), lab.to_string())],
            1,
            "jobs_total is a counter, not a histogram member"
        );
        // The parsed histogram rebuilds into an identical merge source.
        let rebuilt = Histogram::from_cumulative(h).expect("own bounds are accepted");
        let snap = rebuilt.snapshot();
        assert_eq!(snap.count, 1);
        assert_eq!(snap.sum_us, 5);
        assert_eq!(snap.exemplar, Some((0x1f, 5)));
        assert_eq!(snap.buckets[Histogram::bucket_index(5)], 1);
    }

    #[test]
    fn from_cumulative_rejects_foreign_bounds_and_nonmonotone_counts() {
        let h = Histogram::new();
        h.record(17);
        h.record(1_000);
        let mut text = String::new();
        render_histogram_series(&mut text, "x_us", "b=\"0\"", &h.snapshot());
        let full = format!("# HELP x_us x.\n# TYPE x_us histogram\n{text}");
        let parsed = parse_exposition(&full).unwrap();
        let p = &parsed.hists[&("x_us".to_string(), "b=\"0\"".to_string())];
        let ok = Histogram::from_cumulative(p).expect("round trip");
        assert_eq!(ok.snapshot().buckets, h.snapshot().buckets);
        // Foreign bounds: wrong bucket count.
        let mut short = p.clone();
        short.bounds.pop();
        short.cumulative.pop();
        assert!(Histogram::from_cumulative(&short).is_none());
        // Foreign bounds: same count, different edge.
        let mut skewed = p.clone();
        skewed.bounds[0] = 3.0;
        assert!(Histogram::from_cumulative(&skewed).is_none());
        // Corrupt: cumulative counts must be monotone.
        let mut corrupt = p.clone();
        corrupt.cumulative[5] = 10;
        corrupt.cumulative[6] = 3;
        assert!(Histogram::from_cumulative(&corrupt).is_none());
    }

    /// The canonical per-hop render the crate docs describe — a router
    /// family labeled by backend with an exemplar on the covering
    /// bucket — pinned byte-for-byte. If the renderer changes shape,
    /// this test and the crate docs must move together.
    #[test]
    fn docs_example_exposition_is_exact() {
        let h = Histogram::new();
        h.record(1);
        h.record(3);
        h.record_exemplar(3, TraceId(0xabc));
        let mut text = String::new();
        render_labeled_histogram_family(
            &mut text,
            "lpcs_router_submit_forward_us",
            "Router submit forward latency, microseconds.",
            &[("backend=\"0\"".to_string(), h.snapshot())],
        );
        let expected = "\
# HELP lpcs_router_submit_forward_us Router submit forward latency, microseconds.\n\
# TYPE lpcs_router_submit_forward_us histogram\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"1\"} 1\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"2\"} 1\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"4\"} 2 # {trace_id=\"0000000000000abc\"} 3\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"8\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"16\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"32\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"64\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"128\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"256\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"512\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"1024\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"2048\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"4096\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"8192\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"16384\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"32768\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"65536\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"131072\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"262144\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"524288\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"1048576\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"2097152\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"4194304\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"8388608\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"16777216\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"33554432\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"67108864\"} 2\n\
lpcs_router_submit_forward_us_bucket{backend=\"0\",le=\"+Inf\"} 2\n\
lpcs_router_submit_forward_us_sum{backend=\"0\"} 4\n\
lpcs_router_submit_forward_us_count{backend=\"0\"} 2\n";
        assert_eq!(text, expected);
    }
}
