//! TCP front end for [`RecoveryService`] — thread-per-connection, std
//! only (the repo is offline/vendored; no async runtime).
//!
//! Each accepted connection speaks [`super::codec`] frames: `Submit`
//! validates + enqueues (answered by `Submitted`/`Err`), `Cancel` relays
//! into [`RecoveryService::cancel`], `Metrics` returns the counter
//! snapshot, and `Subscribe` bridges the connection onto a push-based
//! [`crate::coordinator::ProgressSub`] — a bounded drop-oldest queue, so
//! a slow or dead client sheds stats instead of ever stalling a worker.
//! While a subscription streams, the connection carries `Progress`
//! frames and ends the stream with exactly one `Done`.
//!
//! Operators arrive by content, so the server keeps a content-addressed
//! cache (`fnv64(problem bytes)` → operator `Arc`): two clients shipping
//! the same Φ share one `Arc`, which is the coordinator's batch identity
//! — wire jobs amortize quantize+pack passes exactly like in-process
//! jobs sharing a handle.

use super::codec::{
    self, fnv64, BackendStats, ErrCode, FrameReader, Message, PollError, WireJobSpec,
};
use crate::coordinator::{
    JobId, Priority, ProgressEvent, ProgressSub, RecoveryService, SubmitError,
};
use crate::linalg::Mat;
use crate::mri::PartialFourierOp;
use crate::telescope::VisibilityOp;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// How often blocked reads/receives wake to check the shutdown flag —
/// the bound on how long `WireServer::shutdown` can wait per thread hop.
const POLL_TICK: Duration = Duration::from_millis(100);
/// A peer that cannot absorb a frame for this long is declared dead
/// (the relay drops the subscription; the job keeps running).
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// Content-addressed operator cache: same bytes → same `Arc` → same
/// [`crate::coordinator::BatchKey`] operator identity. Entries are
/// `Weak`: the cache never extends an operator's lifetime (a dense Φ can
/// be 64 MiB), it only deduplicates operators that are still alive in
/// queued/running jobs — which is exactly when batch identity matters.
/// Dead entries are pruned on every insert.
#[derive(Default)]
struct OpCache {
    dense: HashMap<u64, std::sync::Weak<Mat>>,
    fourier: HashMap<u64, std::sync::Weak<PartialFourierOp>>,
    visibility: HashMap<u64, std::sync::Weak<VisibilityOp>>,
}

/// Reconstruct an in-process spec, sharing operator `Arc`s across
/// submissions that ship identical operator bytes.
///
/// Cheap path first: upgrade the cached `Weak` under a short lock, then
/// verify content OUTSIDE the lock (a dense Φ can be 64 MiB — comparing
/// it must not serialize other connections), and only on a miss pay for
/// operator construction (matrix copy / mask validation + FFT plan).
/// Hash collisions fail the content check and simply bypass the cache.
fn build_spec(ws: WireJobSpec, cache: &Mutex<OpCache>) -> Result<crate::coordinator::JobSpec> {
    let mut key_bytes = Vec::new();
    codec::encode_problem(&mut key_bytes, &ws.problem);
    let key = fnv64(&key_bytes);

    let problem = match &ws.problem {
        codec::WireProblem::Dense { rows, cols, data, shape_tag } => {
            let hit = cache.lock().unwrap().dense.get(&key).and_then(std::sync::Weak::upgrade);
            let phi = match hit {
                Some(hit)
                    if hit.rows == *rows && hit.cols == *cols && hit.data == *data =>
                {
                    hit
                }
                _ => {
                    let fresh = ws.problem.build_handle()?;
                    let phi = fresh.as_dense().expect("dense wire problem").clone();
                    let mut cache = cache.lock().unwrap();
                    cache.dense.retain(|_, w| w.strong_count() > 0);
                    cache.dense.insert(key, Arc::downgrade(&phi));
                    phi
                }
            };
            match shape_tag {
                Some(tag) => crate::coordinator::ProblemHandle::with_shape_tag(phi, tag),
                None => crate::coordinator::ProblemHandle::new(phi),
            }
        }
        codec::WireProblem::PartialFourier { r, kind, fraction, center_band, points, bits } => {
            let hit =
                cache.lock().unwrap().fourier.get(&key).and_then(std::sync::Weak::upgrade);
            let op = match hit {
                Some(hit)
                    if hit.mask().r() == *r
                        && hit.mask().config().kind == *kind
                        && hit.mask().config().fraction == *fraction
                        && hit.mask().config().center_band == *center_band
                        && hit.mask().points() == points.as_slice() =>
                {
                    hit
                }
                _ => {
                    let fresh = ws.problem.build_handle()?;
                    let crate::coordinator::OperatorSpec::PartialFourier { op, .. } =
                        fresh.op
                    else {
                        unreachable!("partial-Fourier wire problem builds a matrix-free handle")
                    };
                    let mut cache = cache.lock().unwrap();
                    cache.fourier.retain(|_, w| w.strong_count() > 0);
                    cache.fourier.insert(key, Arc::downgrade(&op));
                    op
                }
            };
            match bits {
                Some(b) => crate::coordinator::ProblemHandle::low_prec_fourier(op, *b),
                None => crate::coordinator::ProblemHandle::partial_fourier(op),
            }
        }
        codec::WireProblem::Visibility {
            positions,
            freq_hz,
            resolution,
            half_width,
            full,
            bits,
        } => {
            let hit =
                cache.lock().unwrap().visibility.get(&key).and_then(std::sync::Weak::upgrade);
            let op = match hit {
                Some(hit)
                    if hit.array().positions == *positions
                        && hit.array().freq_hz == *freq_hz
                        && hit.grid().resolution == *resolution
                        && hit.grid().half_width == *half_width
                        && hit.full_baselines() == *full =>
                {
                    hit
                }
                _ => {
                    let fresh = ws.problem.build_handle()?;
                    let crate::coordinator::OperatorSpec::Visibility { op, .. } = fresh.op
                    else {
                        unreachable!("visibility wire problem builds a matrix-free handle")
                    };
                    let mut cache = cache.lock().unwrap();
                    cache.visibility.retain(|_, w| w.strong_count() > 0);
                    cache.visibility.insert(key, Arc::downgrade(&op));
                    op
                }
            };
            match bits {
                Some(b) => crate::coordinator::ProblemHandle::low_prec_visibility(op, *b),
                None => crate::coordinator::ProblemHandle::visibility(op),
            }
        }
    };
    Ok(crate::coordinator::JobSpec {
        problem,
        y: ws.y,
        s: ws.s,
        solver: ws.solver,
        engine: ws.engine,
        seed: ws.seed,
        trace: ws.trace,
    })
}

/// Handle to a running wire server. Dropping it only raises the shutdown
/// flag; call [`WireServer::shutdown`] for the bounded join.
pub struct WireServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl WireServer {
    /// The actually-bound address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, wake every connection handler, and join them all.
    /// Bounded: every blocking wait in the server ticks every 100 ms and
    /// re-checks the flag, so no handler can outlive this call.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            h.join().expect("wire accept thread panicked");
        }
        let conns = std::mem::take(&mut *self.conns.lock().unwrap());
        for h in conns {
            h.join().expect("wire connection handler panicked");
        }
    }
}

impl Drop for WireServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }
}

/// Start serving `service` on `listen` (e.g. `"127.0.0.1:0"` for an
/// ephemeral port). `sub_depth` bounds each subscriber's progress queue
/// (drop-oldest beyond it).
pub fn serve(
    service: Arc<RecoveryService>,
    listen: &str,
    sub_depth: usize,
) -> Result<WireServer> {
    let listener =
        TcpListener::bind(listen).with_context(|| format!("binding wire listener on {listen}"))?;
    listener.set_nonblocking(true).context("non-blocking wire listener")?;
    let addr = listener.local_addr().context("wire listener address")?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
    let ops = Arc::new(Mutex::new(OpCache::default()));

    let accept = {
        let shutdown = shutdown.clone();
        let conns = conns.clone();
        std::thread::Builder::new()
            .name("lpcs-wire-accept".into())
            .spawn(move || loop {
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let service = service.clone();
                        let ops = ops.clone();
                        let shutdown = shutdown.clone();
                        let handle = std::thread::Builder::new()
                            .name("lpcs-wire-conn".into())
                            .spawn(move || handle_conn(stream, service, ops, sub_depth, shutdown))
                            .expect("spawn wire connection handler");
                        // Reap handlers that already finished so a
                        // long-running server doesn't accumulate dead
                        // joinable threads connection after connection;
                        // shutdown() still joins every live one.
                        let mut conns = conns.lock().unwrap();
                        conns.retain(|h| !h.is_finished());
                        conns.push(handle);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(_) => std::thread::sleep(Duration::from_millis(10)),
                }
            })
            .expect("spawn wire accept thread")
    };

    Ok(WireServer { addr, shutdown, accept: Some(accept), conns })
}

fn send(conn: &mut TcpStream, msg: &Message) -> std::io::Result<()> {
    let frame = codec::try_encode(msg)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
    conn.write_all(&frame)
}

fn handle_conn(
    mut conn: TcpStream,
    service: Arc<RecoveryService>,
    ops: Arc<Mutex<OpCache>>,
    sub_depth: usize,
    shutdown: Arc<AtomicBool>,
) {
    conn.set_nodelay(true).ok();
    conn.set_read_timeout(Some(POLL_TICK)).ok();
    conn.set_write_timeout(Some(WRITE_TIMEOUT)).ok();
    let mut reader = FrameReader::new();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        let msg = match reader.poll(&mut conn) {
            Ok(None) => continue, // read tick; re-check shutdown
            Ok(Some(msg)) => msg,
            Err(PollError::Closed) | Err(PollError::Io(_)) => return,
            Err(PollError::Decode(e)) => {
                // Corrupt stream: best-effort error frame, then drop the
                // connection (framing can no longer be trusted). A
                // version mismatch gets its own code so mixed-revision
                // fleets diagnose themselves.
                let code = match e {
                    codec::DecodeError::BadVersion(_) => ErrCode::VersionMismatch,
                    _ => ErrCode::Protocol,
                };
                let _ = send(
                    &mut conn,
                    &Message::Err {
                        code,
                        msg: format!("protocol error: {e}"),
                        retry_after_ms: None,
                    },
                );
                return;
            }
        };
        let ok = match msg {
            Message::Submit(ws) => {
                let reply = match build_spec(ws, &ops) {
                    Err(e) => Message::Err {
                        code: ErrCode::Validation,
                        msg: format!("{e:#}"),
                        retry_after_ms: None,
                    },
                    Ok(mut spec) => {
                        // v2/v3 clients submit untraced; this face mints
                        // so the echoed Submitted (and every later frame)
                        // carries the id the fleet will observe.
                        if spec.trace == 0 {
                            spec.trace =
                                crate::obsv::TraceId::mint_submit(&spec.y, spec.s).0;
                        }
                        let trace = spec.trace;
                        match service.try_submit(spec, Priority::Normal) {
                            Ok(id) => Message::Submitted { id, trace },
                            Err(e) => {
                                let code = match e {
                                    SubmitError::Invalid(_) => ErrCode::Validation,
                                    SubmitError::QueueFull => ErrCode::QueueFull,
                                    SubmitError::Closed => ErrCode::Internal,
                                };
                                // Backpressure rejections carry the
                                // scheduler-derived backoff hint.
                                let retry_after_ms = match code {
                                    ErrCode::QueueFull => service.retry_after_ms(),
                                    _ => None,
                                };
                                Message::Err { code, msg: format!("{e}"), retry_after_ms }
                            }
                        }
                    }
                };
                send(&mut conn, &reply).is_ok()
            }
            Message::Subscribe { id } => match service.subscribe(id, sub_depth) {
                None => send(
                    &mut conn,
                    &Message::Err {
                        code: ErrCode::UnknownJob,
                        msg: format!("unknown job {id}"),
                        retry_after_ms: None,
                    },
                )
                .is_ok(),
                Some(sub) => match relay(&sub, id, &mut conn, &service, &shutdown) {
                    RelayEnd::Done => true,
                    RelayEnd::Disconnected | RelayEnd::Shutdown => return,
                },
            },
            Message::Cancel { id } => {
                let accepted = service.cancel(id);
                send(&mut conn, &Message::Cancelled { id, accepted }).is_ok()
            }
            Message::MetricsReq => {
                // Instantaneous queue depth rides along with the counter
                // snapshot — one structured value, one renderer (the
                // legacy key order is pinned byte-compatible by
                // `obsv::ServiceCounters` tests).
                let mut counters = service.metrics().snapshot_struct();
                counters.queue_depth = Some(service.queue_depth() as u64);
                let snapshot = crate::obsv::MetricsSnapshot::Service(counters).render_legacy();
                send(&mut conn, &Message::Metrics { snapshot }).is_ok()
            }
            Message::ScrapeReq => {
                send(&mut conn, &Message::Scrape { text: service.scrape() }).is_ok()
            }
            Message::StatsReq => send(
                &mut conn,
                &Message::Stats(BackendStats {
                    queue_depth: service.queue_depth() as u64,
                    queue_capacity: service.queue_capacity() as u64,
                    workers: service.worker_count() as u64,
                }),
            )
            .is_ok(),
            // Server-bound connections must never carry server→client
            // frames; answer once and keep the (still well-framed)
            // connection alive.
            _ => send(
                &mut conn,
                &Message::Err {
                    code: ErrCode::Protocol,
                    msg: "unexpected server-bound frame".into(),
                    retry_after_ms: None,
                },
            )
            .is_ok(),
        };
        if !ok {
            return; // peer vanished mid-reply
        }
    }
}

enum RelayEnd {
    /// Terminal frame delivered; the connection returns to request mode.
    Done,
    /// The peer died mid-stream: subscription detached, disconnect
    /// counted, job untouched.
    Disconnected,
    Shutdown,
}

/// Pump one subscription onto the socket. The subscription queue is
/// bounded with drop-oldest overflow, so however slow this relay (or its
/// peer) is, the worker thread never blocks — stats are shed here, and
/// the terminal outcome always arrives. While the job is still
/// `Queued`, poll ticks push `QueuePos` frames (only when the position
/// moves), so a subscribed client watches its job walk up the queue.
/// Progress frames carry epoch 0 — the router is the only party that
/// restarts streams and bumps epochs.
fn relay(
    sub: &ProgressSub,
    id: JobId,
    conn: &mut TcpStream,
    service: &RecoveryService,
    shutdown: &AtomicBool,
) -> RelayEnd {
    let mut last_pos: Option<(u64, u64)> = None;
    let trace = service.trace_of(id);
    loop {
        match sub.recv(POLL_TICK) {
            Some(ProgressEvent::Stat(stat)) => {
                if send(conn, &Message::Progress { id, epoch: 0, stat, trace }).is_err() {
                    sub.detach();
                    service.metrics().disconnects.fetch_add(1, Ordering::Relaxed);
                    return RelayEnd::Disconnected;
                }
            }
            Some(ProgressEvent::Terminal(out)) => {
                if send(conn, &Message::Done(out.into())).is_err() {
                    sub.detach();
                    service.metrics().disconnects.fetch_add(1, Ordering::Relaxed);
                    return RelayEnd::Disconnected;
                }
                sub.detach();
                return RelayEnd::Done;
            }
            // Timeout tick. (`None` cannot mean end-of-stream here: this
            // relay is the sole consumer, and the Terminal event returns
            // above the moment it is taken.)
            None => {
                if shutdown.load(Ordering::SeqCst) {
                    sub.detach();
                    return RelayEnd::Shutdown;
                }
                // Position and depth MUST come from one queue-lock
                // snapshot: reading them in two calls lets a drain slip
                // between, publishing a frame where position >= depth.
                if let Some((position, depth)) = service.queue_position_and_depth(id) {
                    let pos = (position as u64, depth as u64);
                    if last_pos != Some(pos) {
                        last_pos = Some(pos);
                        let frame =
                            Message::QueuePos { id, position: pos.0, depth: pos.1 };
                        if send(conn, &frame).is_err() {
                            sub.detach();
                            service.metrics().disconnects.fetch_add(1, Ordering::Relaxed);
                            return RelayEnd::Disconnected;
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;
    use crate::solver::SolverKind;
    use crate::wire::codec::WireProblem;

    #[test]
    fn op_cache_shares_dense_arcs_by_content() {
        let cache = Mutex::new(OpCache::default());
        let ws = |seed: u64| WireJobSpec {
            problem: WireProblem::Dense {
                rows: 2,
                cols: 3,
                data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
                shape_tag: None,
            },
            y: vec![0.0; 2],
            s: 1,
            solver: SolverKind::Niht,
            engine: EngineKind::NativeDense,
            seed,
            trace: 0,
        };
        let a = build_spec(ws(1), &cache).unwrap();
        let b = build_spec(ws(2), &cache).unwrap();
        assert_eq!(a.batch_key(), b.batch_key(), "same bytes → same operator Arc → batchable");
        // Different content gets a different operator identity.
        let mut other = ws(3);
        if let WireProblem::Dense { data, .. } = &mut other.problem {
            data[0] = 9.0;
        }
        let c = build_spec(other, &cache).unwrap();
        assert_ne!(a.batch_key(), c.batch_key());
    }

    #[test]
    fn op_cache_shares_fourier_arcs_by_content() {
        let mask = crate::mri::SamplingMask::generate(
            &crate::mri::MaskConfig::default(),
            16,
            7,
        )
        .unwrap();
        let points: Vec<usize> = mask.points().to_vec();
        let m = 2 * points.len();
        let cache = Mutex::new(OpCache::default());
        let ws = |bits: Option<u8>| WireJobSpec {
            problem: WireProblem::PartialFourier {
                r: 16,
                kind: crate::mri::MaskKind::Cartesian,
                fraction: 0.4,
                center_band: 4,
                points: points.clone(),
                bits,
            },
            y: vec![0.0; m],
            s: 4,
            solver: SolverKind::Niht,
            engine: EngineKind::NativeDense,
            seed: 0,
            trace: 0,
        };
        let a = build_spec(ws(None), &cache).unwrap();
        let b = build_spec(ws(None), &cache).unwrap();
        assert_eq!(a.batch_key(), b.batch_key());
        // A different sampling bit width never shares a batch key.
        let q = build_spec(ws(Some(8)), &cache).unwrap();
        assert_ne!(a.batch_key(), q.batch_key());
    }

    #[test]
    fn op_cache_shares_visibility_arcs_by_content() {
        let cache = Mutex::new(OpCache::default());
        let ws = |bits: Option<u8>, freq_hz: f64| WireJobSpec {
            problem: WireProblem::Visibility {
                positions: vec![[0.0, 0.0], [30.0, 0.0], [0.0, 30.0], [-22.0, 8.5]],
                freq_hz,
                resolution: 6,
                half_width: 0.4,
                full: false,
                bits,
            },
            y: vec![0.0; 12], // 2 · L(L−1)/2, L = 4
            s: 2,
            solver: SolverKind::Niht,
            engine: EngineKind::NativeDense,
            seed: 0,
            trace: 0,
        };
        let a = build_spec(ws(None, 50e6), &cache).unwrap();
        let b = build_spec(ws(None, 50e6), &cache).unwrap();
        assert_eq!(a.batch_key(), b.batch_key(), "same station bytes share one operator Arc");
        // Bit width and station content both split the batch.
        let q = build_spec(ws(Some(8), 50e6), &cache).unwrap();
        assert_ne!(a.batch_key(), q.batch_key());
        let other = build_spec(ws(None, 60e6), &cache).unwrap();
        assert_ne!(a.batch_key(), other.batch_key());
    }
}
