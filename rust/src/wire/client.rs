//! Blocking wire client: submit jobs, watch their convergence live,
//! cancel them, and read service metrics — all over one TCP connection.
//!
//! ```no_run
//! # use lpcs::wire::WireClient;
//! # use lpcs::coordinator::{JobSpec, ProblemHandle};
//! # use std::sync::Arc;
//! # let spec = JobSpec::builder(
//! #     ProblemHandle::new(Arc::new(lpcs::Mat::zeros(4, 8))), vec![0.0; 4], 2,
//! # ).build();
//! let mut client = WireClient::connect("127.0.0.1:7070").unwrap();
//! let id = client.submit(&spec).unwrap();
//! for event in client.watch(id).unwrap() {
//!     match event.unwrap() {
//!         lpcs::wire::WatchEvent::Queued { position, depth } => {
//!             eprintln!("queued at {position}/{depth}")
//!         }
//!         lpcs::wire::WatchEvent::Progress(st) => {
//!             eprintln!("iter {} resid² {:.3e}", st.iter, st.resid_nsq)
//!         }
//!         lpcs::wire::WatchEvent::Done(out) => eprintln!("done: {:?}", out.state),
//!     }
//! }
//! ```
//!
//! Rejections keep their wire [`ErrCode`]: [`WireClient::submit`]
//! returns a typed [`WireError`], so callers (the router above all) can
//! distinguish queue-full backpressure from validation failures without
//! parsing strings.

use super::codec::{self, BackendStats, ErrCode, FrameReader, Message, PollError, WireJobSpec};
use crate::algorithms::IterStat;
use crate::coordinator::{JobId, JobOutcome, JobSpec};
use anyhow::{anyhow, bail, Context, Result};
use std::io::Write;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

/// How long request/reply calls wait for the server's answer.
const REPLY_TIMEOUT: Duration = Duration::from_secs(120);
/// How long [`Watch`] waits between consecutive stream events. Generous:
/// a busy service may queue the job well before its first iteration.
const WATCH_TIMEOUT: Duration = Duration::from_secs(600);
/// Socket read tick (the granularity at which deadlines are checked).
const READ_TICK: Duration = Duration::from_millis(100);

/// One event from a [`Watch`] stream.
#[derive(Debug, Clone)]
pub enum WatchEvent {
    /// The job is still queued: `position` jobs will be taken before
    /// it, out of `depth` currently queued. Re-pushed whenever the
    /// position moves.
    Queued { position: u64, depth: u64 },
    /// A per-iteration stat (possibly with gaps: the server sheds the
    /// oldest stats rather than stall a worker on a slow consumer).
    Progress(IterStat),
    /// The terminal outcome — always the last event of a stream.
    Done(JobOutcome),
}

/// A failed wire request with its rejection category preserved. The
/// vendored `anyhow` shim flattens errors to strings, so typed codes
/// must survive in the error value itself — this is that value.
/// Implements `std::error::Error`, so `?` still lifts it into
/// `anyhow::Result` contexts at call sites that don't care.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError {
    /// The server's typed rejection code; `None` for client-local
    /// failures (connect, timeout, frame corruption).
    pub code: Option<ErrCode>,
    pub msg: String,
    /// Server-estimated backoff for `ErrCode::QueueFull` rejections
    /// (observed exec cost × queue depth); `None` on every other error
    /// and on servers predating wire v4.
    pub retry_after_ms: Option<u64>,
}

impl WireError {
    /// True iff the server rejected with exactly this code.
    pub fn is(&self, code: ErrCode) -> bool {
        self.code == Some(code)
    }

    fn local(e: impl std::fmt::Display) -> Self {
        Self { code: None, msg: e.to_string(), retry_after_ms: None }
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.code {
            Some(code) => write!(f, "{code}: {}", self.msg)?,
            None => f.write_str(&self.msg)?,
        }
        // The hint must live in the rendered message: the vendored
        // anyhow shim flattens errors to strings, and `lpcs solve`/
        // `watch` print exactly this.
        if let Some(ms) = self.retry_after_ms {
            write!(f, " (retry after ~{ms} ms)")?;
        }
        Ok(())
    }
}

impl std::error::Error for WireError {}

/// A blocking client for the wire protocol (one request at a time; open
/// several clients for concurrent streams).
pub struct WireClient {
    stream: TcpStream,
    reader: FrameReader,
    /// Set when a [`Watch`] was abandoned mid-stream: the server may
    /// still be sending `Progress`/`Done` frames for it, so any further
    /// request on this connection would read the stream's leftovers as
    /// its reply. Poisoned clients fail fast instead of desynchronizing.
    poisoned: bool,
}

impl WireClient {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connecting to wire server")?;
        Self::over(stream)
    }

    /// [`WireClient::connect`] with a connect deadline — what the
    /// router's health prober uses, so one dead backend can never stall
    /// a probe round behind a long kernel connect timeout.
    pub fn connect_timeout(addr: impl ToSocketAddrs, timeout: Duration) -> Result<Self> {
        let sa = addr
            .to_socket_addrs()
            .context("resolving wire server address")?
            .next()
            .context("wire server address resolved to nothing")?;
        let stream =
            TcpStream::connect_timeout(&sa, timeout).context("connecting to wire server")?;
        Self::over(stream)
    }

    fn over(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(READ_TICK)).context("setting read timeout")?;
        Ok(Self { stream, reader: FrameReader::new(), poisoned: false })
    }

    fn send(&mut self, msg: &Message) -> Result<()> {
        if self.poisoned {
            bail!(
                "connection desynchronized: a watch was abandoned before its Done frame; \
                 open a new WireClient"
            );
        }
        let frame = codec::try_encode(msg).context("encoding frame")?;
        self.stream.write_all(&frame).context("writing frame")
    }

    /// Next frame within `deadline`, surfacing protocol errors.
    fn recv(&mut self, deadline: Duration) -> Result<Message> {
        let until = Instant::now() + deadline;
        loop {
            match self.reader.poll(&mut self.stream) {
                Ok(Some(msg)) => return Ok(msg),
                Ok(None) => {
                    if Instant::now() >= until {
                        bail!("timed out after {deadline:?} waiting for the server");
                    }
                }
                Err(PollError::Closed) => bail!("server closed the connection"),
                Err(e) => bail!("reading frame: {e}"),
            }
        }
    }

    /// Submit a job; the spec's operator ships by content (dense entries
    /// or mask points), so the server runs exactly this problem. The
    /// error keeps the server's typed [`ErrCode`] (queue-full vs.
    /// validation vs. backend-down) — see [`WireError`].
    pub fn submit(&mut self, spec: &JobSpec) -> std::result::Result<JobId, WireError> {
        self.submit_wire(&WireJobSpec::from_spec(spec))
    }

    /// [`WireClient::submit`] for a spec already in wire form (what a
    /// router holds — forwarding must not round-trip through operator
    /// reconstruction).
    pub fn submit_wire(&mut self, ws: &WireJobSpec) -> std::result::Result<JobId, WireError> {
        self.submit_traced(ws).map(|(id, _)| id)
    }

    /// [`WireClient::submit_wire`] returning `(job id, trace id)`. This
    /// is the fleet's first submit face: an untraced spec (`trace == 0`)
    /// gets its [`crate::obsv::TraceId`] minted here, so the id printed
    /// by `lpcs solve`/`watch` is the one every downstream hop carries.
    pub fn submit_traced(
        &mut self,
        ws: &WireJobSpec,
    ) -> std::result::Result<(JobId, u64), WireError> {
        let mut ws = ws.clone();
        if ws.trace == 0 {
            ws.trace = crate::obsv::TraceId::mint_submit(&ws.y, ws.s).0;
        }
        let sent = ws.trace;
        self.send(&Message::Submit(ws)).map_err(WireError::local)?;
        match self.recv(REPLY_TIMEOUT).map_err(WireError::local)? {
            // A v2/v3 server zeroes the echoed trace; keep the minted one
            // locally so the caller can still label its own records.
            Message::Submitted { id, trace } => {
                Ok((id, if trace != 0 { trace } else { sent }))
            }
            Message::Err { code, msg, retry_after_ms } => Err(WireError {
                code: Some(code),
                msg: format!("submit rejected: {msg}"),
                retry_after_ms,
            }),
            other => Err(WireError::local(format!("unexpected reply to Submit: {other:?}"))),
        }
    }

    /// Stream a job's progress: an iterator of [`WatchEvent`]s ending in
    /// exactly one `Done`. The connection is dedicated to the stream
    /// until then.
    pub fn watch(&mut self, id: JobId) -> Result<Watch<'_>> {
        self.watch_timeout(id, WATCH_TIMEOUT)
    }

    /// [`WireClient::watch`] with an explicit per-event timeout.
    pub fn watch_timeout(&mut self, id: JobId, per_event: Duration) -> Result<Watch<'_>> {
        self.send(&Message::Subscribe { id })?;
        Ok(Watch {
            client: self,
            per_event,
            finished: false,
            clean: false,
            last_iter: None,
            trace: 0,
        })
    }

    /// Ask the service to stop a job at its next iteration boundary.
    /// `Ok(false)` means the job is unknown or already terminal.
    pub fn cancel(&mut self, id: JobId) -> Result<bool> {
        self.send(&Message::Cancel { id })?;
        match self.recv(REPLY_TIMEOUT)? {
            Message::Cancelled { id: got, accepted } if got == id => Ok(accepted),
            Message::Err { code, msg, .. } => bail!("cancel rejected ({code}): {msg}"),
            other => bail!("unexpected reply to Cancel: {other:?}"),
        }
    }

    /// The service's metrics snapshot line.
    pub fn metrics(&mut self) -> Result<String> {
        self.send(&Message::MetricsReq)?;
        match self.recv(REPLY_TIMEOUT)? {
            Message::Metrics { snapshot } => Ok(snapshot),
            Message::Err { code, msg, .. } => bail!("metrics rejected ({code}): {msg}"),
            other => bail!("unexpected reply to Metrics: {other:?}"),
        }
    }

    /// The full Prometheus text exposition (`ScrapeReq` → `Scrape`) —
    /// latency histograms, outcome counters and gauges per
    /// [`crate::obsv`]. What `lpcs scrape ADDR` prints.
    pub fn scrape(&mut self) -> Result<String> {
        self.send(&Message::ScrapeReq)?;
        match self.recv(REPLY_TIMEOUT)? {
            Message::Scrape { text } => Ok(text),
            Message::Err { code, msg, .. } => bail!("scrape rejected ({code}): {msg}"),
            other => bail!("unexpected reply to ScrapeReq: {other:?}"),
        }
    }

    /// One load sample (`StatsReq` → `Stats`): queue depth/capacity and
    /// worker count — the router's health probe.
    pub fn stats(&mut self) -> Result<BackendStats> {
        self.send(&Message::StatsReq)?;
        match self.recv(REPLY_TIMEOUT)? {
            Message::Stats(st) => Ok(st),
            Message::Err { code, msg, .. } => bail!("stats rejected ({code}): {msg}"),
            other => bail!("unexpected reply to StatsReq: {other:?}"),
        }
    }
}

/// Iterator over one job's progress stream. Yields `Err` at most once
/// (protocol violation, timeout, or a server `Err` frame), after which
/// the stream ends.
///
/// Dropping a `Watch` before the stream terminated (before `Done`, or a
/// server `Err` that ends it) **poisons** the client: the connection may
/// still carry this stream's frames, so later requests on it would read
/// them as their replies. Drain the watch to its end — or open a fresh
/// [`WireClient`] — before reusing the connection.
pub struct Watch<'a> {
    client: &'a mut WireClient,
    per_event: Duration,
    finished: bool,
    /// The server ended the stream (Done or stream-ending Err frame):
    /// the connection is at a frame boundary and safe to reuse.
    clean: bool,
    /// Highest iteration already yielded — the resume filter. After a
    /// router failover the upstream job restarts from iteration 0 (the
    /// re-solve is deterministic, so it replays the same trajectory);
    /// already-seen iterations are swallowed here so consumers always
    /// observe one strictly monotone stream across a backend bounce.
    last_iter: Option<usize>,
    /// Fleet trace id observed on the stream's frames (0 until the
    /// first `Progress`/`Done` carries one, or forever against a v2/v3
    /// server).
    trace: u64,
}

impl Watch<'_> {
    /// The job's fleet trace id as observed on the stream so far — what
    /// `lpcs watch` prints and the e2e histogram exemplars carry. 0 =
    /// not seen yet (no traced frame has arrived).
    pub fn trace(&self) -> u64 {
        self.trace
    }
}

impl Iterator for Watch<'_> {
    type Item = Result<WatchEvent>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.finished {
            return None;
        }
        loop {
            return match self.client.recv(self.per_event) {
                Ok(Message::Progress { stat, trace, .. }) => {
                    if trace != 0 {
                        self.trace = trace;
                    }
                    if self.last_iter.is_some_and(|last| stat.iter <= last) {
                        continue; // replayed iteration after a resume
                    }
                    self.last_iter = Some(stat.iter);
                    Some(Ok(WatchEvent::Progress(stat)))
                }
                Ok(Message::QueuePos { position, depth, .. }) => {
                    Some(Ok(WatchEvent::Queued { position, depth }))
                }
                Ok(Message::Done(out)) => {
                    if out.trace != 0 {
                        self.trace = out.trace;
                    }
                    self.finished = true;
                    self.clean = true;
                    Some(Ok(WatchEvent::Done(out.into_outcome())))
                }
                Ok(Message::Err { code, msg, retry_after_ms }) => {
                    // The server answers a bad Subscribe with one Err
                    // frame and sends nothing further for it.
                    self.finished = true;
                    self.clean = true;
                    let we = WireError {
                        code: Some(code),
                        msg: format!("watch failed: {msg}"),
                        retry_after_ms,
                    };
                    Some(Err(we.into()))
                }
                Ok(other) => {
                    self.finished = true;
                    Some(Err(anyhow!("unexpected frame in watch stream: {other:?}")))
                }
                Err(e) => {
                    self.finished = true;
                    Some(Err(e))
                }
            };
        }
    }
}

impl Drop for Watch<'_> {
    fn drop(&mut self) {
        if !self.clean {
            self.client.poisoned = true;
        }
    }
}
