//! Length-prefixed binary frames for the recovery service.
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//! +---------+-------+----------------+------------------+-------------+
//! | version |  tag  | payload length |     payload      |  checksum   |
//! |  1 byte | 1 byte|    u32 LE      | `length` bytes   |   u32 LE    |
//! +---------+-------+----------------+------------------+-------------+
//! ```
//!
//! The checksum is FNV-1a over header + payload, so corruption anywhere
//! in the frame is caught before the payload is interpreted. Decoding is
//! strictly non-panicking: every malformed input maps to a
//! [`DecodeError`] (`Truncated` doubles as the streaming "need more
//! bytes" signal used by [`FrameReader`]).
//!
//! | tag | frame        | direction        | payload |
//! |-----|--------------|------------------|---------|
//! | 1   | `Submit`     | client → server  | [`WireJobSpec`] |
//! | 2   | `Submitted`  | server → client  | job id |
//! | 3   | `Subscribe`  | client → server  | job id |
//! | 4   | `Cancel`     | client → server  | job id |
//! | 5   | `Cancelled`  | server → client  | job id + accepted flag |
//! | 6   | `Progress`   | server → client  | job id + epoch + [`IterStat`] |
//! | 7   | `Done`       | server → client  | [`WireOutcome`] |
//! | 8   | `MetricsReq` | client → server  | (empty) |
//! | 9   | `Metrics`    | server → client  | snapshot string |
//! | 10  | `Err`        | server → client  | [`ErrCode`] (u16) + error string |
//! | 11  | `QueuePos`   | server → client  | job id + queue position + queue depth |
//! | 12  | `StatsReq`   | client → server  | (empty) |
//! | 13  | `Stats`      | server → client  | [`BackendStats`] |
//! | 14  | `ScrapeReq`  | client → server  | (empty) |
//! | 15  | `Scrape`     | server → client  | Prometheus exposition text |
//!
//! The `epoch` on `Progress` is 0 for frames straight off a server; the
//! router bumps it each time it re-subscribes upstream after a backend
//! bounce, so a `watch` client can tell "same stream, resumed" from
//! consecutive iterations. `QueuePos` frames are pushed while a
//! subscribed job is still `Queued`. `StatsReq`/`Stats` is the cheap
//! health/load probe the router polls backends with.
//! `ScrapeReq`/`Scrape` (v3) is the observability face: the server
//! answers with its full Prometheus text exposition (see
//! [`crate::obsv`]); `lpcs scrape ADDR` is a one-shot client for it.
//!
//! v4 appends *trailing* fields to existing payloads: a trace id
//! (u64, 0 = absent) on `Submit`/`Submitted`/`Progress`/`Done`, and an
//! optional `retry_after_ms` hint on `Err`. The decoder reads them only
//! when the frame's version byte says v4, so v2/v3 peers keep decoding
//! unchanged and their frames decode here with zero/`None` defaults.
//!
//! v5 adds operator tag 2 (`Visibility`) inside `Submit` payloads: the
//! matrix-free telescope operator shipped by content — antenna
//! positions and frequency as f64 bit patterns (`f64::to_bits`, exact
//! round-trip), grid resolution + half-width, the unique/full baseline
//! flag and the optional sampling bit width. No frame layout changed,
//! so v2–v4 frames decode as before; only a pre-v5 peer *receiving* a
//! visibility submit rejects it (unknown operator tag → `Malformed`),
//! which the server surfaces as a normal typed `Err`.

use crate::algorithms::qniht::RequantMode;
use crate::algorithms::{IterStat, SolveResult};
use crate::config::EngineKind;
use crate::coordinator::{JobId, JobOutcome, JobSpec, JobState, OperatorSpec, ProblemHandle};
use crate::linalg::Mat;
use crate::mri::{MaskConfig, MaskKind, PartialFourierOp, SamplingMask};
use crate::solver::SolverKind;
use crate::telescope::{AntennaArray, ImageGrid, VisibilityOp};
use std::io::Read;
use std::sync::Arc;
use std::time::Duration;

/// Protocol version carried in every frame header. v2 added typed
/// `Err` codes, the `Progress` epoch, and the `QueuePos`/`Stats`
/// frames; v3 added the `ScrapeReq`/`Scrape` observability pair; v4
/// added the trailing trace id on `Submit`/`Submitted`/`Progress`/
/// `Done` and the `retry_after_ms` hint on `Err`; v5 added the
/// `Visibility` operator tag inside `Submit` payloads. The decoder
/// stays tolerant of older peers back to [`MIN_WIRE_VERSION`] — v4
/// fields are read only from v4+ frames, every older frame decodes
/// with zero/`None` defaults — while v1 peers are rejected with
/// `BadVersion` (surfaced as [`ErrCode::VersionMismatch`] by the
/// server).
pub const WIRE_VERSION: u8 = 5;
/// Oldest peer version [`decode`] accepts.
pub const MIN_WIRE_VERSION: u8 = 2;
/// version + tag + payload-length bytes.
pub const HEADER_LEN: usize = 6;
/// Trailing checksum bytes.
pub const TRAILER_LEN: usize = 4;
/// Upper bound on a payload (a 4096×4096 dense Φ is ~64 MiB; 256 MiB
/// leaves headroom while keeping a corrupt length field from allocating
/// the address space).
pub const MAX_PAYLOAD: usize = 256 << 20;

/// FNV-1a over the given bytes — the frame checksum.
pub fn checksum(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// 64-bit FNV-1a — the content hash behind the server's operator cache
/// and the router's consistent-hash ring (see [`route_key`]).
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// The routing key for a wire job: a content hash over exactly the
/// spec fields that enter `BatchKey` on a backend — the operator bytes
/// (the same encoding the server's op cache hashes), sparsity, solver
/// and engine. Deliberately excludes `y` and `seed`, so every job that
/// would batch together on one node hashes to the same key and the
/// router's consistent-hash ring sends them to the same backend.
pub fn route_key(spec: &WireJobSpec) -> u64 {
    let mut b = Vec::new();
    encode_problem(&mut b, &spec.problem);
    put_u64(&mut b, spec.s as u64);
    put_solver(&mut b, &spec.solver);
    put_engine(&mut b, spec.engine);
    fnv64(&b)
}

/// Why a buffer failed to decode. `Truncated` is recoverable (read more
/// bytes); everything else means the stream is corrupt and the
/// connection should be dropped.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DecodeError {
    /// The buffer ends before the frame does (streaming: need more).
    Truncated,
    /// Version byte is outside [`MIN_WIRE_VERSION`]..=[`WIRE_VERSION`].
    BadVersion(u8),
    /// Checksum mismatch — the frame was corrupted in flight.
    BadChecksum { expect: u32, got: u32 },
    /// Unknown frame tag.
    UnknownTag(u8),
    /// Payload length field exceeds [`MAX_PAYLOAD`].
    TooLarge(usize),
    /// The payload is complete and checksummed but internally malformed.
    Malformed(&'static str),
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Truncated => write!(f, "truncated frame"),
            Self::BadVersion(v) => write!(
                f,
                "unknown wire version {v} (expect {MIN_WIRE_VERSION}..={WIRE_VERSION})"
            ),
            Self::BadChecksum { expect, got } => {
                write!(f, "frame checksum mismatch (expect {expect:#010x}, got {got:#010x})")
            }
            Self::UnknownTag(t) => write!(f, "unknown frame tag {t}"),
            Self::TooLarge(n) => write!(f, "payload length {n} exceeds {MAX_PAYLOAD}"),
            Self::Malformed(what) => write!(f, "malformed payload: {what}"),
        }
    }
}

impl std::error::Error for DecodeError {}

/// Machine-readable rejection category carried on every `Err` frame
/// (u16 on the wire). Stable: codes are append-only so routers and
/// clients built against different minor revisions keep agreeing on
/// what a rejection means.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ErrCode {
    /// The job spec failed validation (shape mismatch, bad bits, ...).
    Validation,
    /// A bounded queue or in-flight table is full — back off and retry;
    /// the apollographql/router `queue_is_full` rejection model: reject
    /// at admission instead of buffering unboundedly.
    QueueFull,
    /// No backend is available to take the job (router-side).
    BackendDown,
    /// The peer speaks a different [`WIRE_VERSION`].
    VersionMismatch,
    /// Subscribe/Cancel named a job id this server never issued.
    UnknownJob,
    /// The peer sent a frame that is illegal in this direction/state.
    Protocol,
    /// Anything else (I/O to a backend, service shutting down, ...).
    Internal,
}

impl ErrCode {
    /// The u16 wire form.
    pub fn code(self) -> u16 {
        match self {
            Self::Validation => 1,
            Self::QueueFull => 2,
            Self::BackendDown => 3,
            Self::VersionMismatch => 4,
            Self::UnknownJob => 5,
            Self::Protocol => 6,
            Self::Internal => 7,
        }
    }

    /// Inverse of [`ErrCode::code`]; `None` for codes this build does
    /// not know (the decoder rejects those frames as malformed).
    pub fn from_code(c: u16) -> Option<Self> {
        Some(match c {
            1 => Self::Validation,
            2 => Self::QueueFull,
            3 => Self::BackendDown,
            4 => Self::VersionMismatch,
            5 => Self::UnknownJob,
            6 => Self::Protocol,
            7 => Self::Internal,
            _ => return None,
        })
    }

    /// Stable lowercase name, used in rendered errors and metrics.
    pub fn name(self) -> &'static str {
        match self {
            Self::Validation => "validation",
            Self::QueueFull => "queue-full",
            Self::BackendDown => "backend-down",
            Self::VersionMismatch => "version-mismatch",
            Self::UnknownJob => "unknown-job",
            Self::Protocol => "protocol",
            Self::Internal => "internal",
        }
    }

    /// All variants, in wire-code order (test matrices iterate this).
    pub const ALL: [ErrCode; 7] = [
        ErrCode::Validation,
        ErrCode::QueueFull,
        ErrCode::BackendDown,
        ErrCode::VersionMismatch,
        ErrCode::UnknownJob,
        ErrCode::Protocol,
        ErrCode::Internal,
    ];
}

impl std::fmt::Display for ErrCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Load snapshot a server answers `StatsReq` with — the router's
/// health probe and admission control read these.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BackendStats {
    /// Jobs currently waiting in the bounded queue.
    pub queue_depth: u64,
    /// Capacity of that queue (admission headroom = capacity − depth).
    pub queue_capacity: u64,
    /// Worker threads serving the queue. A router answers with its
    /// count of *up backends* here.
    pub workers: u64,
}

/// Everything that crosses the wire.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Submit a job (client → server); answered by `Submitted` or `Err`.
    Submit(WireJobSpec),
    /// Job accepted; echoes the trace id the job will carry (v4; 0 from
    /// an older server or for an untraced submit).
    Submitted { id: JobId, trace: u64 },
    /// Stream a job's progress; the connection then carries `QueuePos`/
    /// `Progress` frames until exactly one `Done` (or an immediate
    /// `Err`).
    Subscribe { id: JobId },
    Cancel { id: JobId },
    Cancelled { id: JobId, accepted: bool },
    /// One iteration of a running job. `epoch` is 0 from a direct
    /// server; the router bumps it per upstream re-subscription.
    /// `trace` is the job's trace id (v4; 0 when absent).
    Progress { id: JobId, epoch: u32, stat: IterStat, trace: u64 },
    Done(WireOutcome),
    MetricsReq,
    Metrics { snapshot: String },
    /// Typed rejection. `retry_after_ms` (v4) is the server's estimate
    /// of when a `QueueFull` retry is worth attempting; `None` on other
    /// codes, from older peers, or when the server has no calibrated
    /// cost yet.
    Err { code: ErrCode, msg: String, retry_after_ms: Option<u64> },
    /// Pushed while a subscribed job is still queued: how many jobs sit
    /// ahead of it, and the total queue depth.
    QueuePos { id: JobId, position: u64, depth: u64 },
    StatsReq,
    Stats(BackendStats),
    /// Ask for the Prometheus text exposition (v3).
    ScrapeReq,
    /// The exposition text (`# HELP`/`# TYPE` + series lines; v3).
    Scrape { text: String },
}

impl Message {
    fn tag(&self) -> u8 {
        match self {
            Self::Submit(_) => 1,
            Self::Submitted { .. } => 2,
            Self::Subscribe { .. } => 3,
            Self::Cancel { .. } => 4,
            Self::Cancelled { .. } => 5,
            Self::Progress { .. } => 6,
            Self::Done(_) => 7,
            Self::MetricsReq => 8,
            Self::Metrics { .. } => 9,
            Self::Err { .. } => 10,
            Self::QueuePos { .. } => 11,
            Self::StatsReq => 12,
            Self::Stats(_) => 13,
            Self::ScrapeReq => 14,
            Self::Scrape { .. } => 15,
        }
    }
}

/// A [`JobSpec`] in shippable form: the operator by content (dense
/// entries, or mask points + parameters for the matrix-free path), never
/// by pointer — so a server-side reconstruction runs exactly the math
/// the client described.
#[derive(Debug, Clone, PartialEq)]
pub struct WireJobSpec {
    pub problem: WireProblem,
    pub y: Vec<f32>,
    pub s: usize,
    pub solver: SolverKind,
    pub engine: EngineKind,
    pub seed: u64,
    /// Fleet trace id (v4; 0 = absent). Excluded from [`route_key`] —
    /// tracing must never perturb placement.
    pub trace: u64,
}

/// The operator half of a [`WireJobSpec`].
#[derive(Debug, Clone, PartialEq)]
pub enum WireProblem {
    Dense { rows: usize, cols: usize, data: Vec<f32>, shape_tag: Option<String> },
    PartialFourier {
        r: usize,
        kind: MaskKind,
        fraction: f32,
        center_band: usize,
        points: Vec<usize>,
        bits: Option<u8>,
    },
    /// Matrix-free telescope operator by content (v5): everything that
    /// determines its math, so two clients describing the same station
    /// and grid hash to the same [`route_key`] and share one server-side
    /// operator. f64 parameters travel as exact bit patterns.
    Visibility {
        /// Antenna positions in meters, (x, y) on the station plane.
        positions: Vec<[f64; 2]>,
        /// Observing frequency in Hz.
        freq_hz: f64,
        /// Image grid resolution r (pixels per axis).
        resolution: usize,
        /// Field-of-view half width in direction cosines.
        half_width: f64,
        /// Full L² ordered-pair set instead of the unique baselines.
        full: bool,
        /// Sampling bit width (2|4|8), `None` for the f32 path.
        bits: Option<u8>,
    },
}

impl WireJobSpec {
    /// Lower an in-process spec to wire form (copies the operator
    /// content out of its `Arc`).
    pub fn from_spec(spec: &JobSpec) -> Self {
        let problem = match &spec.problem.op {
            OperatorSpec::Dense(phi) => WireProblem::Dense {
                rows: phi.rows,
                cols: phi.cols,
                data: phi.data.clone(),
                shape_tag: spec.problem.shape_tag.clone(),
            },
            OperatorSpec::PartialFourier { op, bits } => {
                let mask = op.mask();
                let cfg = mask.config();
                WireProblem::PartialFourier {
                    r: mask.r(),
                    kind: cfg.kind,
                    fraction: cfg.fraction,
                    center_band: cfg.center_band,
                    points: mask.points().to_vec(),
                    bits: *bits,
                }
            }
            OperatorSpec::Visibility { op, bits } => WireProblem::Visibility {
                positions: op.array().positions.clone(),
                freq_hz: op.array().freq_hz,
                resolution: op.grid().resolution,
                half_width: op.grid().half_width,
                full: op.full_baselines(),
                bits: *bits,
            },
        };
        Self {
            problem,
            y: spec.y.clone(),
            s: spec.s,
            solver: spec.solver,
            engine: spec.engine,
            seed: spec.seed,
            trace: spec.trace,
        }
    }

    /// Reconstruct an in-process spec (fresh operator `Arc`s). The
    /// server wraps this with a content-addressed cache so jobs shipping
    /// the same operator share one `Arc` and stay batchable.
    pub fn into_spec(self) -> anyhow::Result<JobSpec> {
        let problem = self.problem.build_handle()?;
        Ok(JobSpec {
            problem,
            y: self.y,
            s: self.s,
            solver: self.solver,
            engine: self.engine,
            seed: self.seed,
            trace: self.trace,
        })
    }
}

impl WireProblem {
    /// Build the in-process operator handle this wire problem describes.
    pub fn build_handle(&self) -> anyhow::Result<ProblemHandle> {
        match self {
            Self::Dense { rows, cols, data, shape_tag } => {
                // Checked multiply: `rows`/`cols` arrive from the
                // network, and a lying pair must fail cleanly, not
                // overflow. The payload length bound caps `data`, so the
                // equality gate also caps the allocation below.
                anyhow::ensure!(
                    rows.checked_mul(*cols) == Some(data.len()),
                    "dense operator payload is {} values for a {}x{} matrix",
                    data.len(),
                    rows,
                    cols
                );
                let phi = Arc::new(Mat::from_vec(*rows, *cols, data.clone()));
                Ok(match shape_tag {
                    Some(tag) => ProblemHandle::with_shape_tag(phi, tag),
                    None => ProblemHandle::new(phi),
                })
            }
            Self::PartialFourier { r, kind, fraction, center_band, points, bits } => {
                let cfg =
                    MaskConfig { kind: *kind, fraction: *fraction, center_band: *center_band };
                let mask = SamplingMask::from_points(&cfg, *r, points.clone())?;
                let op = Arc::new(PartialFourierOp::new(mask));
                Ok(match bits {
                    Some(b) => ProblemHandle::low_prec_fourier(op, *b),
                    None => ProblemHandle::partial_fourier(op),
                })
            }
            Self::Visibility { positions, freq_hz, resolution, half_width, full, bits } => {
                // Gate the grid constructor's preconditions first — these
                // values arrive from the network and `ImageGrid::new`
                // asserts; a lying peer must get an error, not a panic.
                anyhow::ensure!(
                    (2..=1024).contains(resolution),
                    "visibility resolution {} out of the servable 2..=1024 range",
                    resolution
                );
                anyhow::ensure!(
                    *half_width > 0.0 && *half_width <= 1.0,
                    "visibility half width {} needs 0 < d <= 1",
                    half_width
                );
                let array =
                    AntennaArray { positions: positions.clone(), freq_hz: *freq_hz };
                let grid = ImageGrid::new(*resolution, *half_width);
                let op = if *full {
                    VisibilityOp::with_full_baselines(array, grid)
                } else {
                    VisibilityOp::new(array, grid)
                };
                // Same station gate the submit face runs, so a hostile
                // operator dies here with the same message.
                op.validate()?;
                let op = Arc::new(op);
                Ok(match bits {
                    Some(b) => ProblemHandle::low_prec_visibility(op, *b),
                    None => ProblemHandle::visibility(op),
                })
            }
        }
    }
}

/// A [`JobOutcome`] in wire form (durations as integer microseconds, so
/// encode/decode round-trips exactly).
#[derive(Debug, Clone, PartialEq)]
pub struct WireOutcome {
    pub id: JobId,
    pub state: JobState,
    pub result: Option<WireResult>,
    pub error: Option<String>,
    pub queued_us: u64,
    pub ran_us: u64,
    /// Fleet trace id (v4; 0 = absent).
    pub trace: u64,
}

/// [`SolveResult`] in wire form.
#[derive(Debug, Clone, PartialEq)]
pub struct WireResult {
    pub x: Vec<f32>,
    pub iterations: u64,
    pub converged: bool,
    pub shrink_events: u64,
    pub history: Vec<IterStat>,
}

impl From<JobOutcome> for WireOutcome {
    fn from(o: JobOutcome) -> Self {
        Self {
            id: o.id,
            state: o.state,
            result: o.result.map(|r| WireResult {
                x: r.x,
                iterations: r.iterations as u64,
                converged: r.converged,
                shrink_events: r.shrink_events as u64,
                history: r.history,
            }),
            error: o.error,
            queued_us: o.queued_for.as_micros() as u64,
            ran_us: o.ran_for.as_micros() as u64,
            trace: o.trace,
        }
    }
}

impl WireOutcome {
    pub fn into_outcome(self) -> JobOutcome {
        JobOutcome {
            id: self.id,
            state: self.state,
            result: self.result.map(|r| SolveResult {
                x: r.x,
                iterations: r.iterations as usize,
                converged: r.converged,
                shrink_events: r.shrink_events as usize,
                history: r.history,
            }),
            error: self.error,
            queued_for: Duration::from_micros(self.queued_us),
            ran_for: Duration::from_micros(self.ran_us),
            trace: self.trace,
        }
    }
}

// ---------------------------------------------------------------------
// Payload primitives
// ---------------------------------------------------------------------

fn put_u8(b: &mut Vec<u8>, v: u8) {
    b.push(v);
}

fn put_bool(b: &mut Vec<u8>, v: bool) {
    b.push(v as u8);
}

fn put_u16(b: &mut Vec<u8>, v: u16) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(b: &mut Vec<u8>, v: f32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_str(b: &mut Vec<u8>, s: &str) {
    put_u32(b, s.len() as u32);
    b.extend_from_slice(s.as_bytes());
}

fn put_vec_f32(b: &mut Vec<u8>, v: &[f32]) {
    put_u32(b, v.len() as u32);
    for &x in v {
        put_f32(b, x);
    }
}

fn put_vec_u64(b: &mut Vec<u8>, v: impl ExactSizeIterator<Item = u64>) {
    put_u32(b, v.len() as u32);
    for x in v {
        put_u64(b, x);
    }
}

fn put_opt(b: &mut Vec<u8>, present: bool) {
    b.push(present as u8);
}

/// Bounds-checked payload reader: every `take_*` fails with `Malformed`
/// instead of slicing out of range, so a checksummed-but-lying payload
/// can never panic the decoder.
struct Rd<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Rd<'a> {
    fn new(b: &'a [u8]) -> Self {
        Self { b, off: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        if self.b.len() - self.off < n {
            return Err(DecodeError::Malformed("payload underrun"));
        }
        let s = &self.b[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, DecodeError> {
        Ok(self.take(1)?[0])
    }

    fn bool(&mut self) -> Result<bool, DecodeError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(DecodeError::Malformed("bool byte not 0/1")),
        }
    }

    fn u16(&mut self) -> Result<u16, DecodeError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, DecodeError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, DecodeError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32, DecodeError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Length prefix for a sequence of `elem_size`-byte elements,
    /// pre-checked against the remaining payload so a lying count can't
    /// drive a huge allocation.
    fn seq_len(&mut self, elem_size: usize) -> Result<usize, DecodeError> {
        let n = self.u32()? as usize;
        if n.saturating_mul(elem_size) > self.b.len() - self.off {
            return Err(DecodeError::Malformed("sequence length exceeds payload"));
        }
        Ok(n)
    }

    fn string(&mut self) -> Result<String, DecodeError> {
        let n = self.seq_len(1)?;
        std::str::from_utf8(self.take(n)?)
            .map(str::to_owned)
            .map_err(|_| DecodeError::Malformed("string is not UTF-8"))
    }

    fn vec_f32(&mut self) -> Result<Vec<f32>, DecodeError> {
        let n = self.seq_len(4)?;
        (0..n).map(|_| self.f32()).collect()
    }

    fn vec_u64(&mut self) -> Result<Vec<u64>, DecodeError> {
        let n = self.seq_len(8)?;
        (0..n).map(|_| self.u64()).collect()
    }

    fn opt(&mut self) -> Result<bool, DecodeError> {
        self.bool()
    }

    fn finish(self) -> Result<(), DecodeError> {
        if self.off == self.b.len() {
            Ok(())
        } else {
            Err(DecodeError::Malformed("trailing payload bytes"))
        }
    }
}

// ---------------------------------------------------------------------
// Struct payloads
// ---------------------------------------------------------------------

fn put_stat(b: &mut Vec<u8>, st: &IterStat) {
    put_u64(b, st.iter as u64);
    put_f32(b, st.resid_nsq);
    put_f32(b, st.mu);
    put_bool(b, st.support_changed);
    put_u64(b, st.shrink_count as u64);
}

fn rd_stat(r: &mut Rd) -> Result<IterStat, DecodeError> {
    Ok(IterStat {
        iter: r.u64()? as usize,
        resid_nsq: r.f32()?,
        mu: r.f32()?,
        support_changed: r.bool()?,
        shrink_count: r.u64()? as usize,
    })
}

fn put_solver(b: &mut Vec<u8>, s: &SolverKind) {
    match s {
        SolverKind::Niht => put_u8(b, 0),
        SolverKind::Iht => put_u8(b, 1),
        SolverKind::Qniht { bits_phi, bits_y, mode } => {
            put_u8(b, 2);
            put_u8(b, *bits_phi);
            put_u8(b, *bits_y);
            put_u8(b, matches!(*mode, RequantMode::Fresh) as u8);
        }
        SolverKind::Cosamp => put_u8(b, 3),
        SolverKind::Fista { lambda, debias } => {
            put_u8(b, 4);
            put_opt(b, lambda.is_some());
            if let Some(l) = lambda {
                put_f32(b, *l);
            }
            put_bool(b, *debias);
        }
    }
}

fn rd_solver(r: &mut Rd) -> Result<SolverKind, DecodeError> {
    Ok(match r.u8()? {
        0 => SolverKind::Niht,
        1 => SolverKind::Iht,
        2 => {
            let bits_phi = r.u8()?;
            let bits_y = r.u8()?;
            let mode = match r.u8()? {
                0 => RequantMode::Fixed,
                1 => RequantMode::Fresh,
                _ => return Err(DecodeError::Malformed("unknown requant mode")),
            };
            SolverKind::Qniht { bits_phi, bits_y, mode }
        }
        3 => SolverKind::Cosamp,
        4 => {
            let lambda = if r.opt()? { Some(r.f32()?) } else { None };
            SolverKind::Fista { lambda, debias: r.bool()? }
        }
        _ => return Err(DecodeError::Malformed("unknown solver tag")),
    })
}

fn put_engine(b: &mut Vec<u8>, e: EngineKind) {
    put_u8(
        b,
        match e {
            EngineKind::NativeDense => 0,
            EngineKind::NativeQuant => 1,
            EngineKind::XlaQuant => 2,
            EngineKind::XlaDense => 3,
            EngineKind::FpgaModel => 4,
        },
    );
}

fn rd_engine(r: &mut Rd) -> Result<EngineKind, DecodeError> {
    Ok(match r.u8()? {
        0 => EngineKind::NativeDense,
        1 => EngineKind::NativeQuant,
        2 => EngineKind::XlaQuant,
        3 => EngineKind::XlaDense,
        4 => EngineKind::FpgaModel,
        _ => return Err(DecodeError::Malformed("unknown engine tag")),
    })
}

/// Encode just the operator half — also the content key the server's op
/// cache hashes, so "same operator" is literally "same bytes".
pub(crate) fn encode_problem(b: &mut Vec<u8>, p: &WireProblem) {
    match p {
        WireProblem::Dense { rows, cols, data, shape_tag } => {
            put_u8(b, 0);
            put_u64(b, *rows as u64);
            put_u64(b, *cols as u64);
            put_vec_f32(b, data);
            put_opt(b, shape_tag.is_some());
            if let Some(tag) = shape_tag {
                put_str(b, tag);
            }
        }
        WireProblem::PartialFourier { r, kind, fraction, center_band, points, bits } => {
            put_u8(b, 1);
            put_u64(b, *r as u64);
            put_u8(b, matches!(*kind, MaskKind::Radial) as u8);
            put_f32(b, *fraction);
            put_u64(b, *center_band as u64);
            put_vec_u64(b, points.iter().map(|&p| p as u64));
            put_opt(b, bits.is_some());
            if let Some(bits) = bits {
                put_u8(b, *bits);
            }
        }
        WireProblem::Visibility { positions, freq_hz, resolution, half_width, full, bits } => {
            put_u8(b, 2);
            // f64 parameters as exact bit patterns: encode/decode must
            // reconstruct the identical steering phases, and the op
            // cache hashes these bytes as the operator's identity.
            put_u32(b, positions.len() as u32);
            for p in positions {
                put_u64(b, p[0].to_bits());
                put_u64(b, p[1].to_bits());
            }
            put_u64(b, freq_hz.to_bits());
            put_u64(b, *resolution as u64);
            put_u64(b, half_width.to_bits());
            put_bool(b, *full);
            put_opt(b, bits.is_some());
            if let Some(bits) = bits {
                put_u8(b, *bits);
            }
        }
    }
}

fn rd_problem(r: &mut Rd) -> Result<WireProblem, DecodeError> {
    Ok(match r.u8()? {
        0 => {
            let rows = r.u64()? as usize;
            let cols = r.u64()? as usize;
            let data = r.vec_f32()?;
            let shape_tag = if r.opt()? { Some(r.string()?) } else { None };
            WireProblem::Dense { rows, cols, data, shape_tag }
        }
        1 => {
            let rr = r.u64()? as usize;
            let kind = match r.u8()? {
                0 => MaskKind::Cartesian,
                1 => MaskKind::Radial,
                _ => return Err(DecodeError::Malformed("unknown mask kind")),
            };
            let fraction = r.f32()?;
            let center_band = r.u64()? as usize;
            let points = r.vec_u64()?.into_iter().map(|p| p as usize).collect();
            let bits = if r.opt()? { Some(r.u8()?) } else { None };
            WireProblem::PartialFourier { r: rr, kind, fraction, center_band, points, bits }
        }
        2 => {
            let np = r.seq_len(16)?;
            let mut positions = Vec::with_capacity(np);
            for _ in 0..np {
                positions.push([f64::from_bits(r.u64()?), f64::from_bits(r.u64()?)]);
            }
            let freq_hz = f64::from_bits(r.u64()?);
            let resolution = r.u64()? as usize;
            let half_width = f64::from_bits(r.u64()?);
            let full = r.bool()?;
            let bits = if r.opt()? { Some(r.u8()?) } else { None };
            WireProblem::Visibility { positions, freq_hz, resolution, half_width, full, bits }
        }
        _ => return Err(DecodeError::Malformed("unknown operator tag")),
    })
}

// v4 trailing fields (the outcome trace id) are appended by the caller
// and read back version-conditionally in `decode` — `put_outcome`/
// `rd_outcome` cover the v2/v3-stable prefix.
fn put_outcome(b: &mut Vec<u8>, o: &WireOutcome) {
    put_u64(b, o.id);
    put_u8(
        b,
        match o.state {
            JobState::Queued => 0,
            JobState::Running => 1,
            JobState::Done => 2,
            JobState::Failed => 3,
        },
    );
    put_opt(b, o.result.is_some());
    if let Some(res) = &o.result {
        put_vec_f32(b, &res.x);
        put_u64(b, res.iterations);
        put_bool(b, res.converged);
        put_u64(b, res.shrink_events);
        put_u32(b, res.history.len() as u32);
        for st in &res.history {
            put_stat(b, st);
        }
    }
    put_opt(b, o.error.is_some());
    if let Some(e) = &o.error {
        put_str(b, e);
    }
    put_u64(b, o.queued_us);
    put_u64(b, o.ran_us);
}

fn rd_outcome(r: &mut Rd) -> Result<WireOutcome, DecodeError> {
    let id = r.u64()?;
    let state = match r.u8()? {
        0 => JobState::Queued,
        1 => JobState::Running,
        2 => JobState::Done,
        3 => JobState::Failed,
        _ => return Err(DecodeError::Malformed("unknown job state")),
    };
    let result = if r.opt()? {
        let x = r.vec_f32()?;
        let iterations = r.u64()?;
        let converged = r.bool()?;
        let shrink_events = r.u64()?;
        let n = r.seq_len(25)?; // 8 + 4 + 4 + 1 + 8 bytes per stat
        let history = (0..n).map(|_| rd_stat(r)).collect::<Result<_, _>>()?;
        Some(WireResult { x, iterations, converged, shrink_events, history })
    } else {
        None
    };
    let error = if r.opt()? { Some(r.string()?) } else { None };
    Ok(WireOutcome { id, state, result, error, queued_us: r.u64()?, ran_us: r.u64()?, trace: 0 })
}

// ---------------------------------------------------------------------
// Frame encode / decode
// ---------------------------------------------------------------------

/// Encode a message into one checksummed frame.
///
/// Panics if the payload exceeds [`MAX_PAYLOAD`] — use [`try_encode`]
/// on trust boundaries where the message size is caller-controlled
/// (an oversized operator must surface as an `Err`, not a panic).
pub fn encode(msg: &Message) -> Vec<u8> {
    try_encode(msg).expect("frame payload exceeds MAX_PAYLOAD")
}

/// [`encode`], returning [`DecodeError::TooLarge`] instead of panicking
/// when the message cannot fit a legal frame.
pub fn try_encode(msg: &Message) -> Result<Vec<u8>, DecodeError> {
    let mut payload = Vec::new();
    match msg {
        Message::Submit(spec) => {
            encode_problem(&mut payload, &spec.problem);
            put_vec_f32(&mut payload, &spec.y);
            put_u64(&mut payload, spec.s as u64);
            put_solver(&mut payload, &spec.solver);
            put_engine(&mut payload, spec.engine);
            put_u64(&mut payload, spec.seed);
            put_u64(&mut payload, spec.trace); // v4 trailing field
        }
        Message::Submitted { id, trace } => {
            put_u64(&mut payload, *id);
            put_u64(&mut payload, *trace); // v4 trailing field
        }
        Message::Subscribe { id } | Message::Cancel { id } => {
            put_u64(&mut payload, *id);
        }
        Message::Cancelled { id, accepted } => {
            put_u64(&mut payload, *id);
            put_bool(&mut payload, *accepted);
        }
        Message::Progress { id, epoch, stat, trace } => {
            put_u64(&mut payload, *id);
            put_u32(&mut payload, *epoch);
            put_stat(&mut payload, stat);
            put_u64(&mut payload, *trace); // v4 trailing field
        }
        Message::Done(out) => {
            put_outcome(&mut payload, out);
            put_u64(&mut payload, out.trace); // v4 trailing field
        }
        Message::MetricsReq => {}
        Message::Metrics { snapshot } => put_str(&mut payload, snapshot),
        Message::Err { code, msg, retry_after_ms } => {
            put_u16(&mut payload, code.code());
            put_str(&mut payload, msg);
            // v4 trailing field
            put_opt(&mut payload, retry_after_ms.is_some());
            if let Some(ms) = retry_after_ms {
                put_u64(&mut payload, *ms);
            }
        }
        Message::QueuePos { id, position, depth } => {
            put_u64(&mut payload, *id);
            put_u64(&mut payload, *position);
            put_u64(&mut payload, *depth);
        }
        Message::StatsReq => {}
        Message::Stats(st) => {
            put_u64(&mut payload, st.queue_depth);
            put_u64(&mut payload, st.queue_capacity);
            put_u64(&mut payload, st.workers);
        }
        Message::ScrapeReq => {}
        Message::Scrape { text } => put_str(&mut payload, text),
    }
    if payload.len() > MAX_PAYLOAD {
        return Err(DecodeError::TooLarge(payload.len()));
    }
    let mut frame = Vec::with_capacity(HEADER_LEN + payload.len() + TRAILER_LEN);
    frame.push(WIRE_VERSION);
    frame.push(msg.tag());
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(&payload);
    let sum = checksum(&frame);
    frame.extend_from_slice(&sum.to_le_bytes());
    Ok(frame)
}

/// Decode one frame from the front of `buf`. Returns the message and the
/// number of bytes consumed; [`DecodeError::Truncated`] means the buffer
/// holds only part of a frame (read more and retry).
pub fn decode(buf: &[u8]) -> Result<(Message, usize), DecodeError> {
    if buf.len() < HEADER_LEN {
        return Err(DecodeError::Truncated);
    }
    // Tolerant of older peers back to MIN_WIRE_VERSION: v3 only ADDED
    // the Scrape pair, v4 fields are trailing — read them only when the
    // sender's version byte says they are there — and v5 only added an
    // operator tag no older peer emits.
    if !(MIN_WIRE_VERSION..=WIRE_VERSION).contains(&buf[0]) {
        return Err(DecodeError::BadVersion(buf[0]));
    }
    let v4 = buf[0] >= 4;
    let tag = buf[1];
    let len = u32::from_le_bytes(buf[2..6].try_into().unwrap()) as usize;
    if len > MAX_PAYLOAD {
        return Err(DecodeError::TooLarge(len));
    }
    let total = HEADER_LEN + len + TRAILER_LEN;
    if buf.len() < total {
        return Err(DecodeError::Truncated);
    }
    let body_end = HEADER_LEN + len;
    let got = u32::from_le_bytes(buf[body_end..total].try_into().unwrap());
    let expect = checksum(&buf[..body_end]);
    if got != expect {
        return Err(DecodeError::BadChecksum { expect, got });
    }
    let mut r = Rd::new(&buf[HEADER_LEN..body_end]);
    let msg = match tag {
        1 => {
            let problem = rd_problem(&mut r)?;
            let y = r.vec_f32()?;
            let s = r.u64()? as usize;
            let solver = rd_solver(&mut r)?;
            let engine = rd_engine(&mut r)?;
            let seed = r.u64()?;
            let trace = if v4 { r.u64()? } else { 0 };
            Message::Submit(WireJobSpec { problem, y, s, solver, engine, seed, trace })
        }
        2 => {
            let id = r.u64()?;
            let trace = if v4 { r.u64()? } else { 0 };
            Message::Submitted { id, trace }
        }
        3 => Message::Subscribe { id: r.u64()? },
        4 => Message::Cancel { id: r.u64()? },
        5 => Message::Cancelled { id: r.u64()?, accepted: r.bool()? },
        6 => {
            let id = r.u64()?;
            let epoch = r.u32()?;
            let stat = rd_stat(&mut r)?;
            let trace = if v4 { r.u64()? } else { 0 };
            Message::Progress { id, epoch, stat, trace }
        }
        7 => {
            let mut out = rd_outcome(&mut r)?;
            if v4 {
                out.trace = r.u64()?;
            }
            Message::Done(out)
        }
        8 => Message::MetricsReq,
        9 => Message::Metrics { snapshot: r.string()? },
        10 => {
            let code = ErrCode::from_code(r.u16()?)
                .ok_or(DecodeError::Malformed("unknown err code"))?;
            let msg = r.string()?;
            let retry_after_ms = if v4 {
                if r.opt()? { Some(r.u64()?) } else { None }
            } else {
                None
            };
            Message::Err { code, msg, retry_after_ms }
        }
        11 => Message::QueuePos { id: r.u64()?, position: r.u64()?, depth: r.u64()? },
        12 => Message::StatsReq,
        13 => Message::Stats(BackendStats {
            queue_depth: r.u64()?,
            queue_capacity: r.u64()?,
            workers: r.u64()?,
        }),
        14 => Message::ScrapeReq,
        15 => Message::Scrape { text: r.string()? },
        t => return Err(DecodeError::UnknownTag(t)),
    };
    r.finish()?;
    Ok((msg, total))
}

// ---------------------------------------------------------------------
// Streaming reader
// ---------------------------------------------------------------------

/// Why [`FrameReader::poll`] gave up on a stream.
#[derive(Debug)]
pub enum PollError {
    /// Peer closed the connection (clean EOF at a frame boundary or not).
    Closed,
    /// Hard I/O error (reset, broken pipe, ...).
    Io(std::io::Error),
    /// The byte stream is corrupt; the connection must be dropped.
    Decode(DecodeError),
}

impl std::fmt::Display for PollError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Closed => write!(f, "connection closed"),
            Self::Io(e) => write!(f, "io error: {e}"),
            Self::Decode(e) => write!(f, "decode error: {e}"),
        }
    }
}

impl std::error::Error for PollError {}

/// Incremental frame reassembly over a blocking `Read` with a read
/// timeout: partial reads accumulate in an internal buffer, and
/// `Ok(None)` on timeout lets the caller check shutdown flags between
/// frames without ever tearing a frame apart.
#[derive(Debug, Default)]
pub struct FrameReader {
    buf: Vec<u8>,
}

impl FrameReader {
    pub fn new() -> Self {
        Self::default()
    }

    /// Next complete frame, `Ok(None)` on read timeout (the reader keeps
    /// any partial frame buffered for the next poll).
    pub fn poll(&mut self, stream: &mut impl Read) -> Result<Option<Message>, PollError> {
        loop {
            match decode(&self.buf) {
                Ok((msg, used)) => {
                    self.buf.drain(..used);
                    return Ok(Some(msg));
                }
                Err(DecodeError::Truncated) => {} // need more bytes
                Err(e) => return Err(PollError::Decode(e)),
            }
            let mut chunk = [0u8; 16 * 1024];
            match stream.read(&mut chunk) {
                Ok(0) => return Err(PollError::Closed),
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    return Ok(None)
                }
                Err(e) => return Err(PollError::Io(e)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stat(iter: usize) -> IterStat {
        IterStat { iter, resid_nsq: 0.25, mu: 1.5, support_changed: true, shrink_count: 2 }
    }

    #[test]
    fn simple_frames_round_trip() {
        for msg in [
            Message::Submitted { id: 7, trace: 0xfeed },
            Message::Submitted { id: 8, trace: 0 },
            Message::Subscribe { id: u64::MAX },
            Message::Cancel { id: 0 },
            Message::Cancelled { id: 3, accepted: true },
            Message::Progress { id: 9, epoch: 2, stat: stat(4), trace: 0xabc },
            Message::MetricsReq,
            Message::Metrics { snapshot: "submitted=1".into() },
            Message::Metrics { snapshot: String::new() },
            Message::Err {
                code: ErrCode::QueueFull,
                msg: "queue full".into(),
                retry_after_ms: Some(120),
            },
            Message::Err { code: ErrCode::Internal, msg: "x".into(), retry_after_ms: None },
            Message::QueuePos { id: 11, position: 3, depth: 9 },
            Message::StatsReq,
            Message::Stats(BackendStats { queue_depth: 5, queue_capacity: 256, workers: 2 }),
            Message::ScrapeReq,
            Message::Scrape { text: "# TYPE lpcs_jobs_total counter\n".into() },
            Message::Scrape { text: String::new() },
        ] {
            let frame = encode(&msg);
            let (back, used) = decode(&frame).unwrap();
            assert_eq!(back, msg);
            assert_eq!(used, frame.len());
        }
    }

    #[test]
    fn two_frames_in_one_buffer_decode_in_order() {
        let a = Message::Submitted { id: 1, trace: 0 };
        let b = Message::Err { code: ErrCode::Internal, msg: "x".into(), retry_after_ms: None };
        let mut buf = encode(&a);
        buf.extend_from_slice(&encode(&b));
        let (first, used) = decode(&buf).unwrap();
        assert_eq!(first, a);
        let (second, used2) = decode(&buf[used..]).unwrap();
        assert_eq!(second, b);
        assert_eq!(used + used2, buf.len());
    }

    /// Fabricate what an older-version peer would have sent: strip the
    /// v4 trailing bytes from the payload, rewrite the version byte,
    /// fix the length field and recompute the checksum (which covers
    /// header + payload).
    fn downgrade(frame: &[u8], version: u8, strip: usize) -> Vec<u8> {
        let len = u32::from_le_bytes(frame[2..6].try_into().unwrap()) as usize;
        let mut out = frame[..HEADER_LEN + len - strip].to_vec();
        out[0] = version;
        out[2..6].copy_from_slice(&((len - strip) as u32).to_le_bytes());
        let sum = checksum(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    #[test]
    fn v2_and_v3_frames_still_decode_with_zeroed_v4_fields() {
        // (sent message, bytes a pre-v4 sender would not have appended,
        //  what this decoder should see)
        let cases: Vec<(Message, usize, Message)> = vec![
            (
                Message::Submitted { id: 42, trace: 0xbeef },
                8,
                Message::Submitted { id: 42, trace: 0 },
            ),
            (Message::MetricsReq, 0, Message::MetricsReq),
            (
                Message::QueuePos { id: 1, position: 0, depth: 4 },
                0,
                Message::QueuePos { id: 1, position: 0, depth: 4 },
            ),
            (
                Message::Progress { id: 3, epoch: 1, stat: stat(6), trace: 7 },
                8,
                Message::Progress { id: 3, epoch: 1, stat: stat(6), trace: 0 },
            ),
            (
                Message::Err { code: ErrCode::Internal, msg: "x".into(), retry_after_ms: None },
                1,
                Message::Err { code: ErrCode::Internal, msg: "x".into(), retry_after_ms: None },
            ),
            (
                Message::Err {
                    code: ErrCode::QueueFull,
                    msg: "full".into(),
                    retry_after_ms: Some(55),
                },
                9,
                Message::Err { code: ErrCode::QueueFull, msg: "full".into(), retry_after_ms: None },
            ),
        ];
        for (sent, strip, want) in cases {
            for version in [2u8, 3] {
                let frame = downgrade(&encode(&sent), version, strip);
                let (back, used) = decode(&frame).expect("older peer frames stay decodable");
                assert_eq!(back, want, "v{version} fabrication of {sent:?}");
                assert_eq!(used, frame.len());
            }
        }
        // A v4 peer appends the trailing fields itself; its frames
        // decode here (v5) with nothing zeroed.
        let sent = Message::Submitted { id: 42, trace: 0xbeef };
        let frame = downgrade(&encode(&sent), 4, 0);
        let (back, _) = decode(&frame).expect("v4 frames stay decodable");
        assert_eq!(back, sent);
    }

    #[test]
    fn version_checksum_tag_and_length_are_enforced() {
        let frame = encode(&Message::Submitted { id: 5, trace: 0 });
        // Version byte (v1 and future versions are both rejected; the
        // checksum is recomputed so version is the only fault).
        for v in [1u8, 9] {
            let mut bad = frame.clone();
            bad[0] = v;
            let body_end = bad.len() - TRAILER_LEN;
            let sum = checksum(&bad[..body_end]);
            let end = bad.len();
            bad[body_end..end].copy_from_slice(&sum.to_le_bytes());
            assert_eq!(decode(&bad), Err(DecodeError::BadVersion(v)));
        }
        // Flipped payload byte → checksum mismatch.
        let mut bad = frame.clone();
        bad[HEADER_LEN] ^= 0xFF;
        assert!(matches!(decode(&bad), Err(DecodeError::BadChecksum { .. })));
        // Flipped checksum byte.
        let mut bad = frame.clone();
        *bad.last_mut().unwrap() ^= 1;
        assert!(matches!(decode(&bad), Err(DecodeError::BadChecksum { .. })));
        // Unknown tag (checksum recomputed so only the tag is wrong).
        let mut bad = frame.clone();
        bad[1] = 200;
        let body_end = bad.len() - TRAILER_LEN;
        let sum = checksum(&bad[..body_end]);
        bad[body_end..].copy_from_slice(&sum.to_le_bytes());
        assert_eq!(decode(&bad), Err(DecodeError::UnknownTag(200)));
        // Absurd length field.
        let mut bad = frame;
        bad[2..6].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(decode(&bad), Err(DecodeError::TooLarge(_))));
    }

    #[test]
    fn err_codes_round_trip_and_unknown_codes_are_malformed() {
        for code in ErrCode::ALL {
            assert_eq!(ErrCode::from_code(code.code()), Some(code));
            let frame = encode(&Message::Err { code, msg: "x".into(), retry_after_ms: None });
            let (back, _) = decode(&frame).unwrap();
            assert_eq!(back, Message::Err { code, msg: "x".into(), retry_after_ms: None });
        }
        // An Err frame carrying a code this build does not know must be
        // rejected as malformed, not mapped to some arbitrary variant.
        let mut frame = vec![WIRE_VERSION, 10];
        let mut payload = Vec::new();
        put_u16(&mut payload, 999);
        put_str(&mut payload, "future code");
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&payload);
        let sum = checksum(&frame);
        frame.extend_from_slice(&sum.to_le_bytes());
        assert_eq!(decode(&frame), Err(DecodeError::Malformed("unknown err code")));
    }

    #[test]
    fn every_truncation_is_rejected_without_panicking() {
        let msg = Message::Progress { id: 1, epoch: 0, stat: stat(3), trace: 9 };
        let frame = encode(&msg);
        for cut in 0..frame.len() {
            assert_eq!(
                decode(&frame[..cut]),
                Err(DecodeError::Truncated),
                "prefix of {cut} bytes"
            );
        }
    }

    #[test]
    fn checksummed_but_lying_payload_is_malformed_not_a_panic() {
        // A Progress frame whose payload is too short for its fields.
        let mut frame = vec![WIRE_VERSION, 6];
        frame.extend_from_slice(&4u32.to_le_bytes());
        frame.extend_from_slice(&[1, 2, 3, 4]);
        let sum = checksum(&frame);
        frame.extend_from_slice(&sum.to_le_bytes());
        assert!(matches!(decode(&frame), Err(DecodeError::Malformed(_))));
        // A string whose length prefix exceeds the payload (valid err
        // code first, so the failure is the string read, not the code).
        let mut frame = vec![WIRE_VERSION, 10];
        frame.extend_from_slice(&6u32.to_le_bytes());
        frame.extend_from_slice(&ErrCode::Validation.code().to_le_bytes());
        frame.extend_from_slice(&1000u32.to_le_bytes());
        let sum = checksum(&frame);
        frame.extend_from_slice(&sum.to_le_bytes());
        assert!(matches!(decode(&frame), Err(DecodeError::Malformed(_))));
    }

    fn vis_spec(bits: Option<u8>) -> WireJobSpec {
        WireJobSpec {
            problem: WireProblem::Visibility {
                positions: vec![[0.0, 0.0], [35.0, -4.0], [-11.5, 20.25]],
                freq_hz: 50e6,
                resolution: 4,
                half_width: 0.4,
                full: false,
                bits,
            },
            y: vec![0.5; 6],
            s: 2,
            solver: SolverKind::Niht,
            engine: EngineKind::NativeDense,
            seed: 9,
            trace: 0,
        }
    }

    #[test]
    fn visibility_submits_round_trip_and_build() {
        for bits in [None, Some(2), Some(8)] {
            let spec = vis_spec(bits);
            let frame = encode(&Message::Submit(spec.clone()));
            let (back, used) = decode(&frame).unwrap();
            assert_eq!(used, frame.len());
            let Message::Submit(got) = back else { panic!("expected a submit frame") };
            assert_eq!(got, spec);
            let handle = got.problem.build_handle().unwrap();
            assert_eq!(handle.op.m(), 2 * 3, "L=3 unique baselines stacked-real");
            assert_eq!(handle.op.n(), 16);
        }
    }

    #[test]
    fn visibility_route_key_tracks_operator_content_and_bits() {
        let a = vis_spec(Some(8));
        let mut b = vis_spec(Some(8));
        b.y = vec![9.0; 6];
        b.seed = 77;
        assert_eq!(route_key(&a), route_key(&b), "y and seed never perturb placement");
        assert_ne!(route_key(&a), route_key(&vis_spec(Some(2))), "bits enter the key");
        let mut d = vis_spec(Some(8));
        let WireProblem::Visibility { positions, .. } = &mut d.problem else { unreachable!() };
        positions[1][0] += 1.0;
        assert_ne!(route_key(&a), route_key(&d), "station content enters the key");
    }

    #[test]
    fn hostile_visibility_parameters_fail_cleanly() {
        let break_one = |f: &mut dyn FnMut(&mut WireProblem)| {
            let mut spec = vis_spec(None);
            f(&mut spec.problem);
            spec.problem.build_handle()
        };
        let half = break_one(&mut |p| {
            let WireProblem::Visibility { half_width, .. } = p else { unreachable!() };
            *half_width = 3.0;
        });
        assert!(half.unwrap_err().to_string().contains("half width"));
        let res = break_one(&mut |p| {
            let WireProblem::Visibility { resolution, .. } = p else { unreachable!() };
            *resolution = 0;
        });
        assert!(res.unwrap_err().to_string().contains("resolution"));
        let nan = break_one(&mut |p| {
            let WireProblem::Visibility { positions, .. } = p else { unreachable!() };
            positions[0][1] = f64::NAN;
        });
        assert!(nan.unwrap_err().to_string().contains("finite"));
        let lone = break_one(&mut |p| {
            let WireProblem::Visibility { positions, .. } = p else { unreachable!() };
            positions.truncate(1);
        });
        assert!(lone.unwrap_err().to_string().contains("antennas"));
    }

    #[test]
    fn visibility_submit_truncations_are_rejected() {
        let frame = encode(&Message::Submit(vis_spec(Some(8))));
        for cut in 0..frame.len() {
            assert_eq!(
                decode(&frame[..cut]),
                Err(DecodeError::Truncated),
                "prefix of {cut} bytes"
            );
        }
    }

    #[test]
    fn frame_reader_reassembles_split_frames() {
        let msg = Message::Metrics { snapshot: "completed=3".into() };
        let frame = encode(&msg);
        // Feed the frame one byte at a time through a reader whose
        // source times out between bytes.
        struct Dribble {
            data: Vec<u8>,
            off: usize,
        }
        impl Read for Dribble {
            fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
                if self.off >= self.data.len() {
                    return Err(std::io::ErrorKind::WouldBlock.into());
                }
                out[0] = self.data[self.off];
                self.off += 1;
                Ok(1)
            }
        }
        let mut src = Dribble { data: frame, off: 0 };
        let mut reader = FrameReader::new();
        let mut got = None;
        for _ in 0..1000 {
            match reader.poll(&mut src).unwrap() {
                Some(m) => {
                    got = Some(m);
                    break;
                }
                None => continue,
            }
        }
        assert_eq!(got, Some(msg));
    }
}
