//! # Streaming wire protocol for the recovery service
//!
//! Turns the in-process [`crate::coordinator::RecoveryService`] into a
//! network service with **live convergence streams**: clients *watch* a
//! recovery converge (per-iteration residuals — the quantity NIHT's
//! convergence theory says to monitor, and what makes low-precision
//! trade-offs observable while a job runs) instead of polling it.
//!
//! Std-only TCP, no async runtime (the repo is offline/vendored):
//!
//! * [`codec`] — length-prefixed, version-tagged, checksummed binary
//!   frames with non-panicking decode (see the frame table there).
//! * [`server`] — `lpcs serve --listen <addr>`: thread-per-connection
//!   front end that bridges `Subscribe` frames onto bounded drop-oldest
//!   [`crate::coordinator::ProgressSub`] queues (a slow client sheds
//!   stats, never stalls a worker), relays `Cancel` into the service,
//!   and shares wire-shipped operators by content so wire jobs batch
//!   exactly like in-process ones.
//! * [`client`] — blocking [`WireClient`]: `submit`, `watch` (iterator
//!   of stats ending in exactly one outcome), `cancel`, `metrics`; the
//!   `lpcs watch <addr> <job>` CLI rides on it.
//!
//! Served results are **bit-identical** to
//! `Recovery::service_dispatch` for every [`crate::solver::SolverKind`]
//! and operator (dense and matrix-free MRI alike) — pinned end to end by
//! `tests/wire_serving.rs` on a [`crate::testkit::harness::ServiceHarness`].
//!
//! The [`crate::router`] tier speaks this same protocol on both of its
//! faces: frames carry typed [`ErrCode`]s, a resume epoch on
//! `Progress`, queue-position pushes while a job is `Queued`, and the
//! `StatsReq`/`Stats` load probe the router's health checker polls.
//! v4 threads a fleet trace id through `Submit`/`Submitted`/`Progress`/
//! `Done` and a `retry_after_ms` hint on queue-full `Err` frames; the
//! decoder stays tolerant back to [`MIN_WIRE_VERSION`].

pub mod client;
pub mod codec;
pub mod server;

pub use client::{Watch, WatchEvent, WireClient, WireError};
pub use codec::{
    checksum, decode, encode, fnv64, route_key, try_encode, BackendStats, DecodeError, ErrCode,
    FrameReader, Message, PollError, WireJobSpec, WireOutcome, WireProblem, WireResult,
    MIN_WIRE_VERSION, WIRE_VERSION,
};
pub use server::{serve, WireServer};
