//! Bench: PJRT engine step latency vs the native engines — the cost of
//! running the AOT JAX/Pallas artifact per NIHT step (compile amortization,
//! literal marshalling, execute) — plus the `obsv` recording overhead on
//! the serving solve path (budget: <1% of a job's wall time).

use lpcs::algorithms::qniht::{QuantKernel, RequantMode};
use lpcs::algorithms::NihtKernel;
use lpcs::benchkit::JsonReporter;
use lpcs::linalg::Mat;
use lpcs::obsv::{Histogram, JobLabels, Outcome, ServiceObsv, TraceId};
use lpcs::rng::XorShift128Plus;
use lpcs::runtime::{XlaDenseKernel, XlaQuantKernel};
use std::path::Path;

fn main() {
    let dir = Path::new("artifacts");
    let (m, n, s) = (256usize, 512usize, 32usize);
    let mut rng = XorShift128Plus::new(1);
    let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
    let mut x_true = vec![0.0f32; n];
    for i in rng.choose_k(n, s) {
        x_true[i] = 1.5;
    }
    let y = phi.matvec(&x_true);
    let x0 = vec![0.0f32; n];
    let x_mid = {
        // a partially-converged iterate (exercises the non-initial path)
        let mut k = QuantKernel::new(&phi, &y, 8, 8, RequantMode::Fixed, 1);
        let st = k.full_step(&x0, s);
        st.x_next
    };

    println!(
        "== step latency, gauss_256x512, s={s}, simd backend: {} ==",
        lpcs::simd::backend_name()
    );
    let mut rep = JsonReporter::new("runtime");
    let mut nk = QuantKernel::new(&phi, &y, 8, 8, RequantMode::Fixed, 1);
    rep.run("native quant full_step", 2, 21, || nk.full_step(&x_mid, s));

    // Observability overhead. A served job records into the histograms a
    // fixed number of times (queue-wait, setup, exec, e2e + outcome), so
    // the right comparison is a whole solve vs the same solve plus one
    // job's worth of recording — the delta is the serving-path cost.
    let hist = Histogram::new();
    rep.run("obsv hist record x1024", 2, 21, || {
        let mut acc = 1u64;
        for _ in 0..1024 {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
            hist.record(acc % 4_000_000);
        }
        acc
    });
    let steps = 40usize;
    let solve = |obsv: Option<&ServiceObsv>| {
        let labels = JobLabels { solver: "qniht", engine: "native-quant", bits: 8 };
        if let Some(o) = obsv {
            o.inflight.add(1);
            o.on_running(labels, 120);
        }
        let mut k = QuantKernel::new(&phi, &y, 8, 8, RequantMode::Fixed, 1);
        let mut x = x0.clone();
        if let Some(o) = obsv {
            o.on_setup(labels, 90);
        }
        for _ in 0..steps {
            x = k.full_step(&x, s).x_next;
        }
        if let Some(o) = obsv {
            o.on_terminal(labels, Outcome::Ok, Some(1_800), 2_000, TraceId(0xbe11));
        }
        x
    };
    let obsv = ServiceObsv::new();
    let bare = rep.run("qniht solve path (bare)", 2, 11, || solve(None));
    let instr = rep.run("qniht solve path (+obsv recording)", 2, 11, || solve(Some(&obsv)));
    let delta = (instr.median_s() - bare.median_s()) / bare.median_s() * 100.0;
    println!("obsv recording overhead on the solve path: {delta:+.3}% (budget <1%)");

    if !dir.join("manifest.json").exists() {
        println!("run `make artifacts` first — skipping the XLA engine rows");
        match rep.write_file(".") {
            Ok(path) => println!("\nwrote {}", path.display()),
            Err(e) => eprintln!("\nfailed to write BENCH_runtime.json: {e}"),
        }
        return;
    }

    // The XLA engines fail cleanly when PJRT is unavailable (the offline
    // xla stub errors at client construction) — record the native rows and
    // still emit the JSON trajectory in that case.
    match XlaQuantKernel::new(dir, "gauss_256x512", &phi, &y, 8, 8, 1) {
        Ok(mut xk) => {
            let t0 = std::time::Instant::now();
            let _ = xk.full_step(&x0, s); // includes compile
            println!("xla first step (incl. compile): {:.3?}", t0.elapsed());
            rep.run("xla quant full_step (warm)", 2, 21, || xk.full_step(&x_mid, s));
            rep.run("xla quant apply_step (warm)", 2, 21, || {
                let g = vec![0.01f32; n];
                xk.apply_step(&x_mid, &g, 0.5, s)
            });
        }
        Err(e) => println!("xla quant kernel unavailable ({e}) — skipping"),
    }
    match XlaDenseKernel::new(dir, "gauss_256x512", &phi, &y) {
        Ok(mut dk) => {
            let _ = dk.full_step(&x0, s);
            rep.run("xla dense full_step (warm)", 2, 21, || dk.full_step(&x_mid, s));
        }
        Err(e) => println!("xla dense kernel unavailable ({e}) — skipping"),
    }

    match rep.write_file(".") {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write BENCH_runtime.json: {e}"),
    }
}
