//! Bench: PJRT engine step latency vs the native engines — the cost of
//! running the AOT JAX/Pallas artifact per NIHT step (compile amortization,
//! literal marshalling, execute).

use lpcs::algorithms::qniht::{QuantKernel, RequantMode};
use lpcs::algorithms::NihtKernel;
use lpcs::benchkit::JsonReporter;
use lpcs::linalg::Mat;
use lpcs::rng::XorShift128Plus;
use lpcs::runtime::{XlaDenseKernel, XlaQuantKernel};
use std::path::Path;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("run `make artifacts` first — skipping runtime bench");
        return;
    }
    let (m, n, s) = (256usize, 512usize, 32usize);
    let mut rng = XorShift128Plus::new(1);
    let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
    let mut x_true = vec![0.0f32; n];
    for i in rng.choose_k(n, s) {
        x_true[i] = 1.5;
    }
    let y = phi.matvec(&x_true);
    let x0 = vec![0.0f32; n];
    let x_mid = {
        // a partially-converged iterate (exercises the non-initial path)
        let mut k = QuantKernel::new(&phi, &y, 8, 8, RequantMode::Fixed, 1);
        let st = k.full_step(&x0, s);
        st.x_next
    };

    println!(
        "== step latency, gauss_256x512, s={s}, simd backend: {} ==",
        lpcs::simd::backend_name()
    );
    let mut rep = JsonReporter::new("runtime");
    let mut nk = QuantKernel::new(&phi, &y, 8, 8, RequantMode::Fixed, 1);
    rep.run("native quant full_step", 2, 21, || nk.full_step(&x_mid, s));

    // The XLA engines fail cleanly when PJRT is unavailable (the offline
    // xla stub errors at client construction) — record the native rows and
    // still emit the JSON trajectory in that case.
    match XlaQuantKernel::new(dir, "gauss_256x512", &phi, &y, 8, 8, 1) {
        Ok(mut xk) => {
            let t0 = std::time::Instant::now();
            let _ = xk.full_step(&x0, s); // includes compile
            println!("xla first step (incl. compile): {:.3?}", t0.elapsed());
            rep.run("xla quant full_step (warm)", 2, 21, || xk.full_step(&x_mid, s));
            rep.run("xla quant apply_step (warm)", 2, 21, || {
                let g = vec![0.01f32; n];
                xk.apply_step(&x_mid, &g, 0.5, s)
            });
        }
        Err(e) => println!("xla quant kernel unavailable ({e}) — skipping"),
    }
    match XlaDenseKernel::new(dir, "gauss_256x512", &phi, &y) {
        Ok(mut dk) => {
            let _ = dk.full_step(&x0, s);
            rep.run("xla dense full_step (warm)", 2, 21, || dk.full_step(&x_mid, s));
        }
        Err(e) => println!("xla dense kernel unavailable ({e}) — skipping"),
    }

    match rep.write_file(".") {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write BENCH_runtime.json: {e}"),
    }
}
