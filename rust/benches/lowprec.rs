//! Bench E4 (Fig 5): low-precision matvec kernels vs f32, and the
//! dispatched SIMD backend vs the portable scalar reference.
//!
//! Emits `BENCH_lowprec.json` (median/p10/p90 seconds per kernel × bits)
//! so the perf trajectory is machine-readable across PRs. Kernel names:
//! `packed_matvec/{scalar|dispatched}/{2,4,8}bit`, etc. On machines without
//! AVX2 the dispatched backend auto-selects the scalar (or NEON-stub) path
//! and the two rows coincide.

use lpcs::benchkit::JsonReporter;
use lpcs::linalg::Mat;
use lpcs::lowprec;
use lpcs::perfmodel::cpu::traffic_speedup_bound;
use lpcs::quant::packed::PackedMatrix;
use lpcs::quant::{QuantizedMatrix, Quantizer};
use lpcs::rng::XorShift128Plus;
use lpcs::simd::{self, Backend};

fn dim(var: &str, default: usize) -> usize {
    std::env::var(var).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    // Acceptance-scale matrix (4096×16384 ⇒ 256 MB at f32): larger than LLC
    // so the f32 path is DRAM-bound — the regime the paper's speedup lives
    // in. Override with LPCS_BENCH_M / LPCS_BENCH_N for quick runs.
    let m = dim("LPCS_BENCH_M", 4096);
    let n = dim("LPCS_BENCH_N", 16384);
    let mut rng = XorShift128Plus::new(1);
    let a = Mat::from_fn(m, n, |_, _| rng.gaussian_f32());
    let x = rng.gaussian_vec(n);

    let scalar = simd::by_backend(Backend::Scalar);
    let dispatched = simd::active();
    println!(
        "== Fig 5: per-iteration kernels, {m}x{n}, dispatched backend: {} ==",
        dispatched.name()
    );

    let mut rep = JsonReporter::new("lowprec");
    let f32_stats = rep.run("matvec/f32", 2, 11, || a.matvec(&x));

    for bits in [2u8, 4, 8] {
        let qm = QuantizedMatrix::from_mat(&a, bits, &mut rng);
        let p = PackedMatrix::pack(&qm);

        let s_scalar = rep.run(&format!("packed_matvec/scalar/{bits}bit"), 2, 11, || {
            lowprec::packed_matvec_with(scalar, &p, &x)
        });
        let s_disp = rep.run(&format!("packed_matvec/dispatched/{bits}bit"), 2, 11, || {
            lowprec::packed_matvec_with(dispatched, &p, &x)
        });
        println!(
            "    -> {bits}-bit: {:.2}x over f32, {:.2}x dispatched-over-scalar \
             (traffic bound {:.0}x, bytes {} vs {})",
            f32_stats.median_s() / s_disp.median_s(),
            s_scalar.median_s() / s_disp.median_s(),
            traffic_speedup_bound(bits as u32),
            p.bytes(),
            a.bytes_f32()
        );

        // Multi-RHS sweep: one pass over the packed words serves R
        // right-hand sides, decoding each row once per batch. The quotable
        // comparison is `rhs{R}` (per the whole batch) vs `repeat{R}` (R
        // single-RHS calls): the amortization win is their ratio. Reduced
        // reps — the R=8 sweep at acceptance scale is ~8 matvecs per iter.
        let mut rhs_rng = XorShift128Plus::new(0xB0 + bits as u64);
        let xs: Vec<Vec<f32>> = (0..8).map(|_| rhs_rng.gaussian_vec(n)).collect();
        let mut s_multi4 = None;
        for r in [1usize, 2, 4, 8] {
            let refs: Vec<&[f32]> = xs[..r].iter().map(|v| v.as_slice()).collect();
            rep.run(&format!("packed_matvec_multi/scalar/{bits}bit/rhs{r}"), 2, 7, || {
                lowprec::packed_matvec_multi_with(scalar, &p, &refs)
            });
            let s = rep.run(&format!("packed_matvec_multi/dispatched/{bits}bit/rhs{r}"), 2, 7, || {
                lowprec::packed_matvec_multi_with(dispatched, &p, &refs)
            });
            if r == 4 {
                s_multi4 = Some(s);
            }
        }
        let s_rep4 = rep.run(&format!("packed_matvec_repeat/dispatched/{bits}bit/rhs4"), 2, 7, || {
            xs[..4]
                .iter()
                .map(|xr| lowprec::packed_matvec_with(dispatched, &p, xr))
                .collect::<Vec<_>>()
        });
        println!(
            "    -> {bits}-bit multi-RHS (R=4): {:.2}x over 4 single calls",
            s_rep4.median_s() / s_multi4.expect("r=4 ran").median_s()
        );

        // Pure integer path (both operands quantized).
        let q8 = Quantizer::new(8);
        let (xq, _xscale) = q8.quantize_auto(&x, &mut rng);
        rep.run(&format!("packed_matvec_q8/scalar/{bits}bit"), 2, 11, || {
            lowprec::packed_matvec_q8_with(scalar, &p, &xq, 1.0)
        });
        rep.run(&format!("packed_matvec_q8/dispatched/{bits}bit"), 2, 11, || {
            lowprec::packed_matvec_q8_with(dispatched, &p, &xq, 1.0)
        });

        // Sparse scale-and-add over the packed transposed buffer
        // (|supp| = 30 — the QNIHT step-path shape).
        let qt = qm.transposed();
        let pt = PackedMatrix::pack(&qt);
        // pt rows are Φ's columns: index over the full 0..n row range.
        let idx: Vec<usize> = (0..30).map(|k| k * 133 % n).collect();
        let vals = vec![1.0f32; 30];
        rep.run(&format!("packed_scale_add/dispatched/{bits}bit"), 2, 11, || {
            lowprec::packed_scale_add_with(dispatched, &pt, &idx, &vals)
        });
    }

    println!("\n== unpacked int8 codes path ==");
    let qm8 = QuantizedMatrix::from_mat(&a, 8, &mut rng);
    let v = rng.gaussian_vec(m);
    let s = rep.run("qmatvec/int8", 2, 11, || {
        lowprec::qmatvec(&qm8.codes, m, n, qm8.multiplier(), &x)
    });
    println!("    -> speedup {:.2}x over f32", f32_stats.median_s() / s.median_s());
    rep.run("qmatvec_t/int8", 2, 11, || {
        lowprec::qmatvec_t(&qm8.codes, m, n, qm8.multiplier(), &v)
    });

    println!("\n== sparse scale-and-add (Φ · x_sparse, |supp| = 30) ==");
    let qt8 = qm8.transposed();
    let idx: Vec<usize> = (0..30).map(|k| k * 133 % n).collect();
    let vals = vec![1.0f32; 30];
    rep.run("qmatvec_sparse/int8", 2, 11, || {
        lowprec::qmatvec_sparse(&qt8.codes, n, m, qt8.multiplier(), &idx, &vals)
    });
    rep.run("matvec_sparse/f32", 2, 11, || a.matvec_sparse(&idx, &vals));

    match rep.write_file(".") {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write BENCH_lowprec.json: {e}"),
    }
}
