//! Bench E4 (Fig 5): low-precision matvec kernels vs f32 — per-iteration
//! speedup at the paper's two CPU routines (matvec + sparse scale-and-add).

use lpcs::benchkit;
use lpcs::linalg::Mat;
use lpcs::lowprec;
use lpcs::perfmodel::cpu::traffic_speedup_bound;
use lpcs::quant::packed::PackedMatrix;
use lpcs::quant::QuantizedMatrix;
use lpcs::rng::XorShift128Plus;

fn main() {
    // Paper-scale matrix (LOFAR CS302: M = 900 baselines × N = 65,536
    // pixels ⇒ 236 MB at f32). This is deliberately larger than LLC so the
    // f32 path is DRAM-bound — the regime the paper's speedup lives in.
    let (m, n) = (900usize, 65536usize);
    let mut rng = XorShift128Plus::new(1);
    let a = Mat::from_fn(m, n, |_, _| rng.gaussian_f32());
    let x = rng.gaussian_vec(n);
    let v = rng.gaussian_vec(m);

    println!("== Fig 5: per-iteration kernels, {m}x{n} ==");
    let f32_stats = benchkit::run("matvec f32 (baseline)", 3, 15, || a.matvec(&x));

    for bits in [2u8, 4, 8] {
        let qm = QuantizedMatrix::from_mat(&a, bits, &mut rng);
        let p = PackedMatrix::pack(&qm);
        let s = benchkit::run(
            &format!("matvec packed {bits}-bit"),
            3,
            15,
            || lowprec::packed_matvec(&p, &x),
        );
        println!(
            "    -> speedup {:.2}x (traffic bound {:.0}x, bytes {} vs {})",
            f32_stats.median_s() / s.median_s(),
            traffic_speedup_bound(bits as u32),
            p.bytes(),
            a.bytes_f32()
        );
    }

    println!("\n== unpacked int8 codes path ==");
    let qm8 = QuantizedMatrix::from_mat(&a, 8, &mut rng);
    let s = benchkit::run("matvec int8 codes", 3, 15, || {
        lowprec::qmatvec(&qm8.codes, m, n, qm8.multiplier(), &x)
    });
    println!("    -> speedup {:.2}x over f32", f32_stats.median_s() / s.median_s());
    benchkit::run("matvec_t int8 codes", 3, 15, || {
        lowprec::qmatvec_t(&qm8.codes, m, n, qm8.multiplier(), &v)
    });

    println!("\n== sparse scale-and-add (Φ · x_sparse, |supp| = 30) ==");
    let qt = qm8.transposed();
    let idx: Vec<usize> = (0..30).map(|k| k * 133 % n).collect();
    let vals = vec![1.0f32; 30];
    benchkit::run("qmatvec_sparse (col-contiguous)", 3, 15, || {
        lowprec::qmatvec_sparse(&qt.codes, n, m, qt.multiplier(), &idx, &vals)
    });
    benchkit::run("matvec_sparse f32", 3, 15, || a.matvec_sparse(&idx, &vals));
}
