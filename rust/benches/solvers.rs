//! Bench E3 (Fig 4 cost side): per-solve wall time of every method on the
//! same astro problem — the "fair comparison involves speed" discussion.
//! All solves route through the `solver` facade (same path the service
//! and the repro figures use), so the numbers include facade dispatch.

use lpcs::algorithms::SolveOptions;
use lpcs::benchkit;
use lpcs::solver::{Problem, Recovery, SolverKind};
use lpcs::telescope::{AstroConfig, AstroProblem};
use std::sync::Arc;

fn main() {
    let cfg = AstroConfig {
        antennas: 10,
        resolution: 32,
        sources: 12,
        snr_db: 10.0,
        ..Default::default()
    };
    let p = AstroProblem::build(&cfg, 1);
    let s = cfg.sources;
    let opts = SolveOptions::default().with_max_iters(50);
    println!("== solver wall time, astro M={} N={} s={s}, 50 iters cap ==", p.m(), p.n());

    let problem = Problem::new(Arc::new(p.phi.clone()), p.y.clone(), s);
    let solve = |kind: SolverKind| {
        Recovery::problem(problem.clone())
            .solver(kind)
            .options(opts.clone())
            .seed(1)
            .run()
            .expect("facade solve")
    };

    benchkit::run("niht 32-bit", 1, 7, || solve(SolverKind::Niht));
    benchkit::run("qniht 8&8 fixed", 1, 7, || solve(SolverKind::qniht_fixed(8, 8)));
    benchkit::run("qniht 4&8 fixed", 1, 7, || solve(SolverKind::qniht_fixed(4, 8)));
    benchkit::run("qniht 2&8 fixed", 1, 7, || solve(SolverKind::qniht_fixed(2, 8)));
    benchkit::run("iht (rescaled)", 1, 7, || solve(SolverKind::Iht));
    benchkit::run("cosamp", 1, 7, || solve(SolverKind::Cosamp));
    benchkit::run("fista + debias", 1, 7, || {
        solve(SolverKind::Fista { lambda: None, debias: true })
    });
}
