//! Bench E3 (Fig 4 cost side): per-solve wall time of every method on the
//! same astro problem — the "fair comparison involves speed" discussion.

use lpcs::algorithms::cosamp::cosamp;
use lpcs::algorithms::fista::{fista, FistaOptions};
use lpcs::algorithms::iht::iht;
use lpcs::algorithms::niht::niht_dense;
use lpcs::algorithms::qniht::{qniht, RequantMode};
use lpcs::algorithms::SolveOptions;
use lpcs::benchkit;
use lpcs::telescope::{AstroConfig, AstroProblem};

fn main() {
    let cfg = AstroConfig {
        antennas: 10,
        resolution: 32,
        sources: 12,
        snr_db: 10.0,
        ..Default::default()
    };
    let p = AstroProblem::build(&cfg, 1);
    let s = cfg.sources;
    let opts = SolveOptions { max_iters: 50, ..Default::default() };
    println!("== solver wall time, astro M={} N={} s={s}, 50 iters cap ==", p.m(), p.n());

    benchkit::run("niht 32-bit", 1, 7, || niht_dense(&p.phi, &p.y, s, &opts));
    benchkit::run("qniht 8&8 fixed", 1, 7, || {
        qniht(&p.phi, &p.y, s, 8, 8, RequantMode::Fixed, 1, &opts)
    });
    benchkit::run("qniht 4&8 fixed", 1, 7, || {
        qniht(&p.phi, &p.y, s, 4, 8, RequantMode::Fixed, 1, &opts)
    });
    benchkit::run("qniht 2&8 fixed", 1, 7, || {
        qniht(&p.phi, &p.y, s, 2, 8, RequantMode::Fixed, 1, &opts)
    });
    benchkit::run("iht (rescaled)", 1, 7, || iht(&p.phi, &p.y, s, &opts));
    benchkit::run("cosamp", 1, 7, || cosamp(&p.phi, &p.y, s, &opts));
    benchkit::run("fista + debias", 1, 7, || {
        fista(&p.phi, &p.y, &opts, &FistaOptions { prune_to: Some(s), ..Default::default() })
    });
}
