//! Bench E5 (Fig 6): the FPGA bandwidth-model sweep at paper scale
//! (M = 900 complex = LOFAR CS302, N = 65,536 = 256×256 grid).

use lpcs::perfmodel::fpga::FpgaModel;

fn main() {
    let f = FpgaModel::default();
    let (m, n) = (900usize, 65536usize);
    println!(
        "== Fig 6: FPGA model, P = {} GB/s, {}x{} (paper scale) ==",
        f.bandwidth / 1e9,
        m,
        n
    );
    println!(
        "{:>8} {:>8} {:>14} {:>12} {:>12}",
        "bits_phi", "bits_y", "iter_time_ms", "speedup", "vals/line"
    );
    for (bp, by) in [(32u32, 32u32), (16, 16), (8, 8), (4, 8), (2, 8)] {
        println!(
            "{:>8} {:>8} {:>14.3} {:>12.2} {:>12}",
            bp,
            by,
            f.iteration_time(m, n, bp, by) * 1e3,
            f.iteration_speedup(m, n, bp, by),
            f.values_per_line(bp)
        );
    }

    // End-to-end shape with the paper's implied iteration ratio.
    println!("\nend-to-end (iterations from the paper's 9.19x headline):");
    let t32 = f.end_to_end_time(m, n, 32, 32, 100);
    for (bp, by, iters) in [(32u32, 32u32, 100usize), (8, 8, 120), (4, 8, 140), (2, 8, 174)] {
        let te = f.end_to_end_time(m, n, bp, by, iters);
        println!(
            "  {bp:>2}&{by}-bit: {iters:>4} iters x {:>8.3} ms = {:>8.1} ms  speedup {:.2}x",
            f.iteration_time(m, n, bp, by) * 1e3,
            te * 1e3,
            t32 / te
        );
    }
}
