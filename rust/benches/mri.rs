//! MRI operator + recovery bench: dense-materialized vs matrix-free vs
//! low-precision sampling paths. Writes `BENCH_mri.json` (uploaded by
//! CI's `bench-json` artifact).
//!
//! What the numbers show: the matrix-free `O(n log n)` transforms beat
//! the materialized `m × n` matvec by a widening margin with resolution,
//! and the quantized path adds only the per-block quantize/dequantize of
//! the k-space traffic on top of the f32 transform.

use lpcs::algorithms::SolveOptions;
use lpcs::benchkit::JsonReporter;
use lpcs::mri::{self, MaskConfig, MriConfig, MriProblem, PartialFourierOp, SamplingMask};
use lpcs::solver::{MeasurementOp, Problem, Recovery, SolverKind};
use std::sync::Arc;

fn main() {
    let mut rep = JsonReporter::new("mri");

    println!("== operator application: matrix-free FFT vs materialized DFT matrix ==");
    for r in [32usize, 64] {
        let mask = SamplingMask::generate(&MaskConfig::default(), r, 7).expect("mask");
        let op = PartialFourierOp::new(mask);
        let dense = op.to_mat();
        let x = mri::phantom::sparse_phantom(r, r * r / 12);
        let y = op.apply(&x);
        println!(
            "  r={r}: n={}, m={} ({} samples); dense Φ would hold {:.1} MB",
            MeasurementOp::n(&op),
            MeasurementOp::m(&op),
            op.mask().len(),
            dense.bytes_f32() as f64 / 1e6,
        );
        rep.run(&format!("apply/matrix-free/r{r}"), 2, 15, || op.apply(&x));
        rep.run(&format!("apply/dense/r{r}"), 2, 15, || dense.matvec(&x));
        rep.run(&format!("adjoint/matrix-free/r{r}"), 2, 15, || op.apply_t(&y));
        rep.run(&format!("adjoint/dense/r{r}"), 2, 15, || dense.matvec_t(&y));
    }

    println!("\n== end-to-end recovery (32x32, 25-iteration cap) ==");
    let cfg = MriConfig { resolution: 32, ..Default::default() };
    let p = MriProblem::build(&cfg, 7).expect("problem");
    let opts = SolveOptions::default().with_max_iters(25);
    let dense = Arc::new(p.op.to_mat());
    rep.run("solve/matrix-free-f32/r32", 1, 7, || {
        Recovery::problem(Problem::with_op(p.op.clone(), p.y.clone(), p.s))
            .solver(SolverKind::Niht)
            .options(opts.clone())
            .run()
            .expect("solve")
    });
    rep.run("solve/matrix-free-q8/r32", 1, 7, || {
        Recovery::problem(mri::lowprec_problem(p.op.clone(), &p.y, p.s, 8, 1))
            .solver(SolverKind::Niht)
            .options(opts.clone())
            .run()
            .expect("solve")
    });
    rep.run("solve/dense-materialized-f32/r32", 1, 7, || {
        Recovery::problem(Problem::new(dense.clone(), p.y.clone(), p.s))
            .solver(SolverKind::Niht)
            .options(opts.clone())
            .run()
            .expect("solve")
    });

    match rep.write_file(".") {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write BENCH_mri.json: {e}"),
    }
}
