//! Telescope operator + recovery bench: dense-materialized vs
//! matrix-free vs low-precision sampling paths. Writes
//! `BENCH_astro.json` (uploaded by CI's `bench-json` artifact).
//!
//! What the numbers show: the on-the-fly operator trades `O(M·N)` trig
//! per application for zero operator storage (a dense unique-baseline Φ
//! at L=30/r=64 is ~28 MB), the cached-row mode buys back the trig at
//! the dense path's memory cost, and the quantized path adds only the
//! per-baseline-block quantize/dequantize of the visibility traffic on
//! top of the f32 transform.

use lpcs::algorithms::SolveOptions;
use lpcs::benchkit::JsonReporter;
use lpcs::rng::XorShift128Plus;
use lpcs::solver::{MeasurementOp, Problem, Recovery, SolverKind};
use lpcs::telescope::{op as astro_op, AntennaArray, AstroConfig, ImageGrid, SkyProblem, VisibilityOp};
use std::sync::Arc;

fn main() {
    let mut rep = JsonReporter::new("astro");

    println!("== operator application: on-the-fly trig vs cached rows vs dense ==");
    for r in [32usize, 64] {
        let mut rng = XorShift128Plus::new(7);
        let array = AntennaArray::lofar_like(10, 50e6, &mut rng);
        let op = VisibilityOp::new(array, ImageGrid::new(r, 0.4));
        let cached = op.clone().cached();
        let dense = op.to_mat();
        let x = rng.gaussian_vec(MeasurementOp::n(&op));
        let y = op.apply(&x);
        println!(
            "  r={r}: n={}, m={} ({} unique baselines); dense Φ holds {:.1} MB",
            MeasurementOp::n(&op),
            MeasurementOp::m(&op),
            op.baseline_count(),
            dense.bytes_f32() as f64 / 1e6,
        );
        rep.run(&format!("apply/matrix-free/r{r}"), 2, 15, || op.apply(&x));
        rep.run(&format!("apply/cached-rows/r{r}"), 2, 15, || cached.apply(&x));
        rep.run(&format!("apply/dense/r{r}"), 2, 15, || dense.matvec(&x));
        rep.run(&format!("adjoint/matrix-free/r{r}"), 2, 15, || op.apply_t(&y));
        rep.run(&format!("adjoint/cached-rows/r{r}"), 2, 15, || cached.apply_t(&y));
        rep.run(&format!("adjoint/dense/r{r}"), 2, 15, || dense.matvec_t(&y));
    }

    println!("\n== end-to-end recovery (L=10, 32x32 sky, 25-iteration cap) ==");
    let cfg = AstroConfig {
        antennas: 10,
        resolution: 32,
        sources: 12,
        snr_db: 10.0,
        ..Default::default()
    };
    let p = SkyProblem::build(&cfg, 7).expect("problem");
    let opts = SolveOptions::default().with_max_iters(25);
    let dense = Arc::new(p.op.to_mat());
    rep.run("solve/matrix-free-f32/r32", 1, 7, || {
        Recovery::problem(Problem::with_op(p.op.clone(), p.y.clone(), p.s))
            .solver(SolverKind::Niht)
            .options(opts.clone())
            .run()
            .expect("solve")
    });
    rep.run("solve/matrix-free-q8/r32", 1, 7, || {
        Recovery::problem(astro_op::lowprec_problem(p.op.clone(), &p.y, p.s, 8, 1))
            .solver(SolverKind::Niht)
            .options(opts.clone())
            .run()
            .expect("solve")
    });
    rep.run("solve/dense-materialized-f32/r32", 1, 7, || {
        Recovery::problem(Problem::new(dense.clone(), p.y.clone(), p.s))
            .solver(SolverKind::Niht)
            .options(opts.clone())
            .run()
            .expect("solve")
    });

    match rep.write_file(".") {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write BENCH_astro.json: {e}"),
    }
}
