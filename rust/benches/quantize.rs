//! Bench: stochastic quantization + packing throughput (setup cost of the
//! low-precision path; amortized over the solve in Fixed mode, per
//! iteration in Fresh mode).

use lpcs::benchkit;
use lpcs::linalg::Mat;
use lpcs::quant::packed::PackedMatrix;
use lpcs::quant::{QuantizedMatrix, Quantizer};
use lpcs::rng::XorShift128Plus;

fn main() {
    let (m, n) = (1800usize, 4096usize);
    let mut rng = XorShift128Plus::new(1);
    let a = Mat::from_fn(m, n, |_, _| rng.gaussian_f32());
    let elems = (m * n) as f64;

    println!("== quantization throughput, {m}x{n} ({:.1} M elements) ==", elems / 1e6);
    for bits in [2u8, 4, 8] {
        let mut q_rng = XorShift128Plus::new(2);
        let s = benchkit::run(&format!("quantize {bits}-bit"), 1, 7, || {
            QuantizedMatrix::from_mat(&a, bits, &mut q_rng)
        });
        println!("    -> {:.1} M elem/s", elems / s.median_s() / 1e6);
    }

    let qm = QuantizedMatrix::from_mat(&a, 2, &mut rng);
    let s = benchkit::run("pack 2-bit codes", 1, 7, || PackedMatrix::pack(&qm));
    println!("    -> {:.1} M elem/s", elems / s.median_s() / 1e6);
    let p = PackedMatrix::pack(&qm);
    benchkit::run("unpack 2-bit codes", 1, 7, || p.unpack());

    // Per-element quantize (the scalar hot path).
    let q = Quantizer::new(2);
    let mut r2 = XorShift128Plus::new(3);
    let v = r2.gaussian_vec(1 << 16);
    let s = benchkit::run("quantize_slice 64k", 3, 31, || {
        q.quantize_slice(&v, 1.0, &mut r2)
    });
    println!("    -> {:.1} M elem/s", (1 << 16) as f64 / s.median_s() / 1e6);
}
