//! Bench: the router tier's cost and payoff — per-job relay overhead
//! (routed submit→Done, two wire hops, vs a direct backend, one hop)
//! and the batch-affinity win: a burst of jobs over several operators
//! under consistent hashing (each Φ's jobs land together and batch
//! wide) vs round-robin scatter (each backend sees a mix of keys and
//! the scheduler must cut smaller per-key batches, repeating the
//! quantize+pack). Writes `BENCH_router.json`.

use lpcs::algorithms::SolveOptions;
use lpcs::benchkit::{BenchStats, JsonReporter};
use lpcs::config::{EngineKind, ServiceConfig};
use lpcs::coordinator::{JobSpec, JobState, ProblemHandle};
use lpcs::rng::XorShift128Plus;
use lpcs::testkit::{RouterHarness, ServiceHarness};
use lpcs::wire::{WatchEvent, WireClient};
use lpcs::Mat;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn planted(m: usize, n: usize, s: usize, seed: u64) -> (Arc<Mat>, Vec<f32>) {
    let mut rng = XorShift128Plus::new(seed);
    let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
    let mut x = vec![0.0f32; n];
    for i in rng.choose_k(n, s) {
        x[i] = 1.5;
    }
    let y = phi.matvec(&x);
    (Arc::new(phi), y)
}

fn spec(phi: &Arc<Mat>, y: &[f32], s: usize, seed: u64) -> JobSpec {
    JobSpec::builder(ProblemHandle::new(phi.clone()), y.to_vec(), s)
        .bits(4, 8)
        .engine(EngineKind::NativeQuant)
        .seed(seed)
        .build()
}

fn solve_to_done(client: &mut WireClient, spec: &JobSpec) {
    let id = client.submit(spec).expect("submit");
    for event in client.watch(id).expect("watch") {
        if let WatchEvent::Done(out) = event.expect("stream event") {
            assert_eq!(out.state, JobState::Done, "{:?}", out.error);
        }
    }
}

/// A single wall-clock measurement as recordable stats.
fn once(d: Duration) -> BenchStats {
    BenchStats { iters: 1, median: d, mean: d, p10: d, p90: d }
}

fn main() {
    let (m, n, s) = (128usize, 256usize, 8usize);
    let (phi, y) = planted(m, n, s, 1);
    let opts = SolveOptions { max_iters: 40, ..Default::default() };
    let svc = ServiceConfig {
        workers: 2,
        queue_capacity: 256,
        max_batch: 8,
        max_wait_ms: 2,
        ..Default::default()
    };
    let mut rep = JsonReporter::new("router");

    // Per-job relay overhead: the same solve through one wire hop
    // (client→backend) and through two (client→router→backend).
    {
        let h = ServiceHarness::start(svc, opts.clone());
        let mut c = h.client();
        rep.run("submit→Done direct (1 hop)", 2, 15, || solve_to_done(&mut c, &spec(&phi, &y, s, 1)));
        h.shutdown();
    }
    {
        let h = RouterHarness::start(2, svc, opts.clone());
        let mut c = h.client();
        rep.run("submit→Done routed (2 hops)", 2, 15, || solve_to_done(&mut c, &spec(&phi, &y, s, 1)));
        h.shutdown();
    }

    // Affinity payoff: 32 jobs over 4 operators, submitted interleaved.
    let problems: Vec<(Arc<Mat>, Vec<f32>)> = (0..4).map(|k| planted(m, n, s, 10 + k)).collect();
    let jobs = 32usize;
    for (label, affinity) in
        [("burst 32 jobs × 4 Φ, affinity", true), ("burst 32 jobs × 4 Φ, round-robin", false)]
    {
        let h = RouterHarness::start_with(2, svc, opts.clone(), |c| c.affinity = affinity);
        let mut client = h.client();
        let t0 = Instant::now();
        let ids: Vec<_> = (0..jobs)
            .map(|k| {
                let (phi, y) = &problems[k % problems.len()];
                client.submit(&spec(phi, y, s, k as u64)).expect("routed submit")
            })
            .collect();
        for id in ids {
            for event in client.watch(id).expect("watch") {
                if let WatchEvent::Done(out) = event.expect("stream event") {
                    assert_eq!(out.state, JobState::Done, "{:?}", out.error);
                }
            }
        }
        let wall = t0.elapsed();
        let (batched_jobs, batches) = (0..2)
            .map(|i| {
                let sm = h.backend_service(i).metrics();
                (sm.batched_jobs.load(Ordering::Relaxed), sm.batches.load(Ordering::Relaxed))
            })
            .fold((0u64, 0u64), |acc, t| (acc.0 + t.0, acc.1 + t.1));
        println!(
            "{label}: {jobs} jobs in {wall:>9.3?} = {:>6.1} jobs/s, mean batch {:.2} \
             ({batched_jobs} jobs / {batches} batches)   router: {}",
            jobs as f64 / wall.as_secs_f64(),
            batched_jobs as f64 / batches.max(1) as f64,
            h.router().metrics().snapshot()
        );
        rep.record(label, &once(wall));
        h.shutdown();
    }

    match rep.write_file(".") {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write BENCH_router.json: {e}"),
    }
}
