//! Bench: federated-scrape latency vs fleet size. One `ScrapeReq` at
//! the router fans out to every healthy backend, parses each
//! exposition and merges the histogram families into a single fleet
//! view — so the scrape path costs one serial wire round-trip per
//! backend plus the parse/merge work. This pins how that grows with
//! backend count (1, 2, 4) against the single-backend direct scrape
//! baseline. Writes `BENCH_obsv.json`.

use lpcs::algorithms::SolveOptions;
use lpcs::benchkit::JsonReporter;
use lpcs::config::{EngineKind, ServiceConfig};
use lpcs::coordinator::{JobSpec, JobState, ProblemHandle};
use lpcs::rng::XorShift128Plus;
use lpcs::testkit::RouterHarness;
use lpcs::wire::WatchEvent;
use lpcs::Mat;
use std::sync::Arc;

fn planted(m: usize, n: usize, s: usize, seed: u64) -> (Arc<Mat>, Vec<f32>) {
    let mut rng = XorShift128Plus::new(seed);
    let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
    let mut x = vec![0.0f32; n];
    for i in rng.choose_k(n, s) {
        x[i] = 1.5;
    }
    let y = phi.matvec(&x);
    (Arc::new(phi), y)
}

fn main() {
    let (m, n, s) = (128usize, 256usize, 8usize);
    let opts = SolveOptions { max_iters: 40, ..Default::default() };
    let svc = ServiceConfig {
        workers: 2,
        queue_capacity: 256,
        max_batch: 8,
        max_wait_ms: 2,
        ..Default::default()
    };
    let mut rep = JsonReporter::new("obsv");

    for backends in [1usize, 2, 4] {
        let h = RouterHarness::start(backends, svc, opts.clone());
        // Populate every backend's histograms with real terminal jobs so
        // the scrape parses and merges non-trivial expositions (the
        // round-robin in the affinity-less case would do, but affinity
        // hashing over distinct operators spreads load well enough here).
        for k in 0..(4 * backends as u64) {
            let (phi, y) = planted(m, n, s, 10 + k);
            let spec = JobSpec::builder(ProblemHandle::new(phi), y, s)
                .bits(4, 8)
                .engine(EngineKind::NativeQuant)
                .seed(k)
                .build();
            let mut c = h.client();
            let id = c.submit(&spec).expect("routed submit");
            for event in c.watch(id).expect("watch") {
                if let WatchEvent::Done(out) = event.expect("stream event") {
                    assert_eq!(out.state, JobState::Done, "{:?}", out.error);
                }
            }
        }

        if backends == 1 {
            let mut direct = h.backend_client(0);
            rep.run("backend scrape direct (baseline)", 2, 31, || {
                direct.scrape().expect("direct scrape").len()
            });
        }
        let mut c = h.client();
        let label = format!("federated scrape, {backends} backend(s)");
        let stats = rep.run(&label, 2, 31, || c.scrape().expect("federated scrape").len());
        println!("{label}: median {:?}", stats.median);
        h.shutdown();
    }

    match rep.write_file(".") {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write BENCH_obsv.json: {e}"),
    }
}
