//! Bench E11: recovery-service throughput/latency — queue + batcher +
//! worker-pool overhead on top of the raw (facade) solver.

use lpcs::algorithms::SolveOptions;
use lpcs::benchkit;
use lpcs::config::{EngineKind, ServiceConfig};
use lpcs::coordinator::{JobSpec, ProblemHandle, RecoveryService};
use lpcs::linalg::Mat;
use lpcs::rng::XorShift128Plus;
use lpcs::solver::{Problem, Recovery, SolverKind};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn planted(m: usize, n: usize, s: usize, seed: u64) -> (Arc<Mat>, Vec<f32>) {
    let mut rng = XorShift128Plus::new(seed);
    let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
    let mut x = vec![0.0f32; n];
    for i in rng.choose_k(n, s) {
        x[i] = 1.5;
    }
    let y = phi.matvec(&x);
    (Arc::new(phi), y)
}

fn main() {
    let (m, n, s) = (128usize, 256usize, 8usize);
    let (phi, y) = planted(m, n, s, 1);
    let opts = SolveOptions { max_iters: 40, ..Default::default() };

    // Baseline: one facade solve, no service around it.
    let problem = Problem::new(phi.clone(), y.clone(), s);
    let raw = benchkit::run("raw qniht solve (no service)", 1, 9, || {
        Recovery::problem(problem.clone())
            .solver(SolverKind::qniht_fixed(4, 8))
            .options(opts.clone())
            .seed(1)
            .run()
            .expect("facade solve")
    });

    for workers in [1usize, 2, 4] {
        let service = RecoveryService::start(
            ServiceConfig {
                workers,
                queue_capacity: 256,
                max_batch: 8,
                max_wait_ms: 0,
                ..Default::default()
            },
            opts.clone(),
            "artifacts".into(),
        );
        let jobs = 64;
        let t0 = Instant::now();
        let ids: Vec<_> = (0..jobs)
            .map(|k| {
                service
                    .submit(
                        JobSpec::builder(ProblemHandle::new(phi.clone()), y.clone(), s)
                            .bits(4, 8)
                            .engine(EngineKind::NativeQuant)
                            .seed(k)
                            .build(),
                    )
                    .unwrap()
            })
            .collect();
        for id in ids {
            service.wait(id, Duration::from_secs(120)).expect("job done");
        }
        let wall = t0.elapsed();
        println!(
            "service {workers} workers: {jobs} jobs in {wall:>9.3?} = {:>7.1} jobs/s  \
             (raw solve {:.3?} -> ideal {:.1} jobs/s/worker)  {}",
            jobs as f64 / wall.as_secs_f64(),
            raw.median,
            1.0 / raw.median_s(),
            service.metrics().snapshot()
        );
        service.shutdown();
    }
}
