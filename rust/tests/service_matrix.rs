//! Serving conformance matrix (PR 3): every [`SolverKind`] × compatible
//! engine submitted through `RecoveryService` must return bit-identical
//! x̂ to the direct `Recovery` facade call for the same seed, dispatched
//! the way the service dispatches (`Recovery::service_dispatch` — the
//! batch-composition-independent singleton-batch path). Covers the
//! CoSaMP/FISTA/IHT baselines, QNIHT at every packed width, and the new
//! FPGA-model engine.

use lpcs::algorithms::SolveOptions;
use lpcs::config::{EngineKind, ServiceConfig};
use lpcs::coordinator::{JobSpec, JobState, ProblemHandle, RecoveryService};
use lpcs::perfmodel::fpga::FpgaModel;
use lpcs::rng::XorShift128Plus;
use lpcs::solver::{EngineRegistry, Problem, Recovery, SolverKind};
use lpcs::Mat;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn planted(m: usize, n: usize, s: usize, seed: u64) -> (Arc<Mat>, Vec<f32>) {
    let mut rng = XorShift128Plus::new(seed);
    let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
    let mut x = vec![0.0f32; n];
    for i in rng.choose_k(n, s) {
        x[i] = 2.0 * rng.gaussian_f32().signum() + 0.3 * rng.gaussian_f32();
    }
    let y = phi.matvec(&x);
    (Arc::new(phi), y)
}

/// The full servable matrix: (solver, engine) pairs the native build can
/// execute. The XLA engines need real PJRT bindings (the offline vendor
/// stub fails at client creation), so they are exercised by their
/// dispatch-error tests in `solver_facade.rs` instead.
fn matrix() -> Vec<(SolverKind, EngineKind)> {
    vec![
        (SolverKind::Niht, EngineKind::NativeDense),
        (SolverKind::Iht, EngineKind::NativeDense),
        (SolverKind::Cosamp, EngineKind::NativeDense),
        (SolverKind::Fista { lambda: None, debias: true }, EngineKind::NativeDense),
        (SolverKind::qniht_fixed(2, 8), EngineKind::NativeQuant),
        (SolverKind::qniht_fixed(4, 8), EngineKind::NativeQuant),
        (SolverKind::qniht_fixed(8, 8), EngineKind::NativeQuant),
        (SolverKind::qniht_fixed(2, 8), EngineKind::FpgaModel),
        (SolverKind::qniht_fixed(8, 8), EngineKind::FpgaModel),
    ]
}

#[test]
fn every_solver_kind_is_servable_and_matches_the_facade_bit_for_bit() {
    let service = RecoveryService::start(
        ServiceConfig { workers: 2, queue_capacity: 64, max_batch: 4, ..Default::default() },
        SolveOptions::default(),
        PathBuf::from("artifacts"),
    );
    for (case, (solver, engine)) in matrix().into_iter().enumerate() {
        let (phi, y) = planted(96, 192, 5, 100 + case as u64);
        let seed = 40 + case as u64;

        let direct = Recovery::problem(Problem::new(phi.clone(), y.clone(), 5))
            .solver(solver)
            .engine(engine)
            .seed(seed)
            .service_dispatch()
            .run()
            .unwrap_or_else(|e| panic!("{} on {}: direct run failed: {e:#}", solver.name(), engine.name()));

        let id = service
            .submit(
                JobSpec::builder(ProblemHandle::new(phi), y, 5)
                    .solver(solver)
                    .engine(engine)
                    .seed(seed)
                    .build(),
            )
            .unwrap_or_else(|e| panic!("{} on {}: submit failed: {e:#}", solver.name(), engine.name()));
        let out = service.wait(id, Duration::from_secs(120)).expect("job finishes");
        assert_eq!(out.state, JobState::Done, "{} on {}: {:?}", solver.name(), engine.name(), out.error);
        let served = out.result.unwrap();

        assert_eq!(
            served.x,
            direct.x,
            "{} on {}: served x̂ must be bit-identical to the facade",
            solver.name(),
            engine.name()
        );
        assert_eq!(served.iterations, direct.iterations, "{} on {}", solver.name(), engine.name());
        assert_eq!(served.converged, direct.converged, "{} on {}", solver.name(), engine.name());
    }
    let m = service.metrics();
    assert!(
        m.modeled_us.load(std::sync::atomic::Ordering::Relaxed) > 0,
        "the fpga-model cases accrued modeled time"
    );
    service.shutdown();
}

#[test]
fn fpga_model_matches_native_quant_iterates() {
    // Same math, different clock: for an identical spec the fpga-model
    // engine must reproduce native-quant bit-for-bit through the service.
    let service = RecoveryService::start(
        ServiceConfig { workers: 1, queue_capacity: 16, max_batch: 2, ..Default::default() },
        SolveOptions::default(),
        PathBuf::from("artifacts"),
    );
    let (phi, y) = planted(64, 128, 4, 77);
    let submit = |engine: EngineKind| {
        service
            .submit(
                JobSpec::builder(ProblemHandle::new(phi.clone()), y.clone(), 4)
                    .bits(4, 8)
                    .engine(engine)
                    .seed(9)
                    .build(),
            )
            .unwrap()
    };
    let a = submit(EngineKind::NativeQuant);
    let b = submit(EngineKind::FpgaModel);
    let ra = service.wait(a, Duration::from_secs(60)).unwrap().result.unwrap();
    let rb = service.wait(b, Duration::from_secs(60)).unwrap().result.unwrap();
    assert_eq!(ra.x, rb.x);
    assert_eq!(ra.iterations, rb.iterations);
    service.shutdown();
}

#[test]
fn fpga_model_is_registered_and_bills_iteration_time() {
    let mut reg = EngineRegistry::with_defaults(PathBuf::from("artifacts"));
    assert!(
        reg.names().iter().any(|n| n == "fpga-model"),
        "fpga-model must appear in EngineRegistry::names(): {:?}",
        reg.names()
    );
    let (phi, y) = planted(96, 192, 5, 55);
    let report = Recovery::problem(Problem::new(phi, y, 5))
        .solver(SolverKind::qniht_fixed(2, 8))
        .engine(EngineKind::FpgaModel)
        .seed(3)
        .registry(&mut reg)
        .run()
        .unwrap();
    let metrics = reg.metrics("fpga-model").expect("engine was instantiated");
    // The engine charges exactly iterations × the model's per-iteration
    // streaming time T = size(Φ̂)/P.
    let expect_s =
        FpgaModel::default().iteration_time(96, 192, 2, 8) * report.iterations as f64;
    assert_eq!(metrics.modeled_time_us, (expect_s * 1e6).round() as u64);
    assert!(metrics.modeled_time_us > 0);
    assert_eq!(
        report.modeled,
        Some(Duration::from_micros(metrics.modeled_time_us)),
        "the report surfaces the same modeled time"
    );
}

#[test]
fn served_result_is_independent_of_batch_composition() {
    // The same spec must solve to the same bits whether it lands in a
    // crowd (batched with siblings) or alone — the scheduler reorders
    // and regroups jobs, so this is what makes results reproducible.
    let (phi, y) = planted(64, 128, 4, 31);
    let spec = || {
        JobSpec::builder(ProblemHandle::new(phi.clone()), y.clone(), 4)
            .bits(2, 8)
            .seed(5)
            .build()
    };
    let run = |siblings: usize| {
        let service = RecoveryService::start(
            ServiceConfig {
                workers: 1,
                queue_capacity: 64,
                max_batch: 8,
                max_wait_ms: 5,
                ..Default::default()
            },
            SolveOptions::default(),
            PathBuf::from("artifacts"),
        );
        let mut rng = XorShift128Plus::new(91);
        let ids: Vec<_> = (0..siblings)
            .map(|k| {
                let mut x = vec![0.0f32; 128];
                for i in rng.choose_k(128, 4) {
                    x[i] = 1.0;
                }
                let sib = JobSpec::builder(ProblemHandle::new(phi.clone()), phi.matvec(&x), 4)
                    .bits(2, 8)
                    .seed(1000 + k as u64)
                    .build();
                service.submit(sib).unwrap()
            })
            .collect();
        let probe = service.submit(spec()).unwrap();
        let x = service.wait(probe, Duration::from_secs(120)).unwrap().result.unwrap().x;
        for id in ids {
            service.wait(id, Duration::from_secs(120)).unwrap();
        }
        service.shutdown();
        x
    };
    assert_eq!(run(0), run(5), "batch siblings must not perturb a job's iterate");
}
