//! Integration: the PJRT/XLA execution engines must agree with the native
//! rust engines — the core cross-layer correctness signal (L1/L2 artifacts
//! vs the L3 reference implementation).
//!
//! Requires `make artifacts` (skips gracefully when absent so `cargo test`
//! works in a fresh checkout).

use lpcs::algorithms::niht::{niht_dense, solve};
use lpcs::algorithms::qniht::{QuantKernel, RequantMode};
use lpcs::algorithms::support::support_of;
use lpcs::algorithms::{NihtKernel, SolveOptions};
use lpcs::linalg::Mat;
use lpcs::metrics;
use lpcs::rng::XorShift128Plus;
use lpcs::runtime::{Runtime, XlaDenseKernel, XlaQuantKernel};
use std::path::PathBuf;

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

/// Planted problem at the tiny artifact shape (64×128, s=8).
fn tiny_problem(seed: u64) -> (Mat, Vec<f32>, Vec<f32>, usize) {
    let (m, n, s) = (64usize, 128usize, 8usize);
    let mut rng = XorShift128Plus::new(seed);
    let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
    let mut x = vec![0.0f32; n];
    for i in rng.choose_k(n, s) {
        x[i] = rng.gaussian_f32() + 1.5 * rng.gaussian_f32().signum();
    }
    let y = phi.matvec(&x);
    (phi, y, x, s)
}

#[test]
fn manifest_lists_all_kinds_for_all_shapes() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Runtime::new(&dir).unwrap();
    for tag in rt.manifest().shape_tags() {
        for kind in ["qniht_step", "apply_step", "qgrad", "niht_step_f32", "apply_step_f32"] {
            assert!(
                rt.manifest().find_kind(kind, &tag).is_some(),
                "missing {kind} for {tag}"
            );
        }
    }
}

#[test]
fn xla_dense_solve_matches_native_dense() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let (phi, y, x_true, s) = tiny_problem(1);
    let native = niht_dense(&phi, &y, s, &SolveOptions::default());
    let mut k = XlaDenseKernel::new(&dir, "tiny_64x128", &phi, &y).unwrap();
    let xla = solve(&mut k, s, &SolveOptions::default());
    // Identical control flow over numerically identical steps.
    assert_eq!(native.iterations, xla.iterations);
    let d = metrics::recovery_error(&xla.x, &native.x);
    assert!(d < 1e-4, "engines diverge: {d}");
    assert!(metrics::recovery_error(&xla.x, &x_true) < 1e-2);
}

#[test]
fn xla_quant_solve_matches_native_quant() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let (phi, y, _, s) = tiny_problem(2);
    let seed = 99;
    let mut nk = QuantKernel::new(&phi, &y, 8, 8, RequantMode::Fixed, seed);
    let native = solve(&mut nk, s, &SolveOptions::default());
    let mut xk = XlaQuantKernel::new(&dir, "tiny_64x128", &phi, &y, 8, 8, seed).unwrap();
    let xla = solve(&mut xk, s, &SolveOptions::default());
    // Same seed ⇒ same codes ⇒ same trajectory.
    assert_eq!(support_of(&native.x), support_of(&xla.x));
    let d = metrics::recovery_error(&xla.x, &native.x);
    assert!(d < 1e-3, "engines diverge: {d}");
}

#[test]
fn xla_quant_single_steps_match_native() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let (phi, y, _, s) = tiny_problem(3);
    let seed = 7;
    let mut nk = QuantKernel::new(&phi, &y, 4, 8, RequantMode::Fixed, seed);
    let mut xk = XlaQuantKernel::new(&dir, "tiny_64x128", &phi, &y, 4, 8, seed).unwrap();
    let x0 = vec![0.0f32; 128];
    let a = nk.full_step(&x0, s);
    let b = xk.full_step(&x0, s);
    assert!((a.mu - b.mu).abs() / a.mu.max(1e-9) < 1e-3, "mu {} vs {}", a.mu, b.mu);
    assert!((a.resid_nsq - b.resid_nsq).abs() / a.resid_nsq < 1e-3);
    for (u, v) in a.g.iter().zip(&b.g) {
        assert!((u - v).abs() < 1e-2 * a.g.iter().fold(0f32, |m, &z| m.max(z.abs())));
    }
    assert_eq!(support_of(&a.x_next), support_of(&b.x_next));
}

#[test]
fn xla_apply_step_respects_mu() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let (phi, y, _, s) = tiny_problem(4);
    let mut xk = XlaQuantKernel::new(&dir, "tiny_64x128", &phi, &y, 8, 8, 5).unwrap();
    let x0 = vec![0.0f32; 128];
    let st = xk.full_step(&x0, s);
    // Re-applying at the same mu reproduces the proposal.
    let (x_same, dxn, _) = xk.apply_step(&x0, &st.g, st.mu, s);
    assert_eq!(support_of(&x_same), support_of(&st.x_next));
    assert!((dxn - st.dx_nsq).abs() / st.dx_nsq < 1e-3);
    // A smaller mu gives a smaller move.
    let (_, dxn_small, _) = xk.apply_step(&x0, &st.g, st.mu * 0.25, s);
    assert!(dxn_small < dxn);
}

#[test]
fn artifact_s_is_baked() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let (phi, y, _, _) = tiny_problem(5);
    let k = XlaQuantKernel::new(&dir, "tiny_64x128", &phi, &y, 2, 8, 1).unwrap();
    assert_eq!(k.artifact_s(), 8);
}

#[test]
fn shape_mismatch_is_rejected() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let (phi, y, _, _) = tiny_problem(6);
    // Wrong tag for this problem shape.
    assert!(XlaQuantKernel::new(&dir, "gauss_256x512", &phi, &y, 2, 8, 1).is_err());
}
