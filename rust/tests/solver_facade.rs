//! Integration tests for the unified `solver` facade: registry dispatch
//! parity (facade results must be BIT-IDENTICAL to the direct kernel
//! paths), the unknown-engine error path, batched quantize+pack
//! amortization, observer-driven early stopping, and pluggability of
//! custom measurement operators and custom engines.

use lpcs::algorithms::niht::niht_dense;
use lpcs::algorithms::qniht::{qniht, RequantMode};
use lpcs::algorithms::support::support_of;
use lpcs::algorithms::{
    IterObserver, IterStat, NoopObserver, ObserverSignal, SolveOptions, SolveResult,
};
use lpcs::config::EngineKind;
use lpcs::linalg::Mat;
use lpcs::metrics;
use lpcs::rng::XorShift128Plus;
use lpcs::solver::{
    Engine, EngineContext, EngineRegistry, MeasurementOp, NoopBatchObserver, Problem, Recovery,
    SolveRequest, SolverKind,
};
use std::path::PathBuf;
use std::sync::Arc;

fn planted(m: usize, n: usize, s: usize, seed: u64) -> (Arc<Mat>, Vec<f32>, Vec<f32>) {
    let mut rng = XorShift128Plus::new(seed);
    let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
    let mut x = vec![0.0f32; n];
    for i in rng.choose_k(n, s) {
        x[i] = 2.0 * rng.gaussian_f32().signum() + 0.3 * rng.gaussian_f32();
    }
    let y = phi.matvec(&x);
    (Arc::new(phi), y, x)
}

// ---------------------------------------------------------------- parity

#[test]
fn registry_dense_dispatch_is_bit_identical_to_direct_kernel() {
    let (phi, y, _) = planted(96, 192, 6, 1);
    let opts = SolveOptions::default();
    let direct = niht_dense(&phi, &y, 6, &opts);
    let report = Recovery::problem(Problem::new(phi.clone(), y.clone(), 6))
        .solver(SolverKind::Niht)
        .engine(EngineKind::NativeDense)
        .options(opts)
        .run()
        .unwrap();
    assert_eq!(report.x, direct.x, "facade NativeDense must be bit-identical");
    assert_eq!(report.iterations, direct.iterations);
    assert_eq!(report.converged, direct.converged);
    assert_eq!(report.shrink_events, direct.shrink_events);
}

#[test]
fn registry_quant_dispatch_is_bit_identical_to_direct_kernel() {
    for (bits, mode) in [(8u8, RequantMode::Fixed), (4, RequantMode::Fixed), (2, RequantMode::Fresh)]
    {
        let (phi, y, _) = planted(96, 192, 5, 2 + bits as u64);
        let opts = SolveOptions::default();
        let direct = qniht(&phi, &y, 5, bits, 8, mode, 42, &opts);
        let report = Recovery::problem(Problem::new(phi.clone(), y.clone(), 5))
            .solver(SolverKind::Qniht { bits_phi: bits, bits_y: 8, mode })
            .engine(EngineKind::NativeQuant)
            .options(opts)
            .seed(42)
            .run()
            .unwrap();
        assert_eq!(
            report.x, direct.x,
            "facade NativeQuant ({bits}-bit, {mode:?}) must be bit-identical"
        );
        assert_eq!(report.iterations, direct.iterations);
    }
}

// ----------------------------------------------------------- error paths

#[test]
fn unknown_engine_name_is_a_clean_error() {
    let (phi, y, _) = planted(32, 64, 3, 5);
    let err = Recovery::problem(Problem::new(phi, y, 3))
        .engine_named("antimatter")
        .run()
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown engine 'antimatter'"), "{err}");
    assert!(err.contains("native-quant"), "lists known engines: {err}");
}

#[test]
fn xla_engine_without_shape_tag_is_rejected() {
    let (phi, y, _) = planted(32, 64, 3, 6);
    let err = Recovery::problem(Problem::new(phi, y, 3))
        .solver(SolverKind::qniht_fixed(2, 8))
        .engine(EngineKind::XlaQuant)
        .run()
        .unwrap_err()
        .to_string();
    // Fails before any PJRT work: either the missing tag or (if a
    // manifest were present) the offline stub. The tag check comes first.
    assert!(err.contains("shape tag") || err.contains("manifest"), "{err}");
}

// ----------------------------------------------- batching & amortization

#[test]
fn batched_solve_quantizes_phi_once_and_recovers_every_job() {
    let (phi, _, _) = planted(96, 192, 4, 7);
    let opts = SolveOptions::default();
    let mut rng = XorShift128Plus::new(70);
    let mut truths = Vec::new();
    let reqs: Vec<SolveRequest> = (0..4)
        .map(|j| {
            let mut x = vec![0.0f32; 192];
            for i in rng.choose_k(192, 4) {
                x[i] = 1.5 + rng.uniform_f32();
            }
            let y = phi.matvec(&x);
            truths.push(x);
            SolveRequest {
                problem: Problem::new(phi.clone(), y, 4),
                solver: SolverKind::qniht_fixed(8, 8),
                seed: j,
            }
        })
        .collect();

    let mut reg = EngineRegistry::with_defaults(PathBuf::from("artifacts"));
    let results = reg
        .solve_batch("native-quant", &reqs, &opts, &mut NoopBatchObserver)
        .unwrap();
    assert_eq!(results.len(), 4);
    for (result, x_true) in results.iter().zip(&truths) {
        let r = result.as_ref().expect("batched job solves");
        assert_eq!(support_of(&r.x), support_of(x_true));
    }

    let m = reg.metrics("native-quant").expect("engine was used");
    assert_eq!(m.phi_quantizations, 1, "ONE quantize+pack for the whole batch");
    assert_eq!(m.solves, 4);
    assert_eq!(m.amortized_batches, 1);

    // The same four jobs solved individually quantize Φ four times.
    for req in &reqs {
        reg.solve("native-quant", req, &opts, &mut NoopObserver).unwrap();
    }
    let m = reg.metrics("native-quant").unwrap();
    assert_eq!(m.phi_quantizations, 5, "per-job path pays one quantization each");
    assert_eq!(m.solves, 8);
}

#[test]
fn batched_results_do_not_depend_on_batch_composition() {
    // The shared Φ̂ is a pure function of (Φ, bits): a job solved in a
    // batch of 4 must produce the same iterate as in a batch of 2.
    let (phi, y, _) = planted(64, 128, 4, 8);
    let opts = SolveOptions::default();
    let req = |seed: u64, y: &[f32]| SolveRequest {
        problem: Problem::new(phi.clone(), y.to_vec(), 4),
        solver: SolverKind::qniht_fixed(4, 8),
        seed,
    };
    // Second observation against the SAME Φ.
    let y2 = {
        let mut rng = XorShift128Plus::new(90);
        let mut x = vec![0.0f32; 128];
        for i in rng.choose_k(128, 4) {
            x[i] = 1.0;
        }
        phi.matvec(&x)
    };

    let mut reg = EngineRegistry::with_defaults(PathBuf::from("artifacts"));
    let big = reg
        .solve_batch(
            "native-quant",
            &[req(1, &y), req(2, &y2), req(3, &y), req(4, &y2)],
            &opts,
            &mut NoopBatchObserver,
        )
        .unwrap();
    let small = reg
        .solve_batch("native-quant", &[req(1, &y), req(2, &y2)], &opts, &mut NoopBatchObserver)
        .unwrap();
    // A job that arrives alone (batch of ONE) must match too — the shared
    // Φ̂ seed is canonical, not taken from any batch member.
    let solo = reg
        .solve_batch("native-quant", &[req(1, &y)], &opts, &mut NoopBatchObserver)
        .unwrap();
    assert_eq!(
        big[0].as_ref().unwrap().x,
        small[0].as_ref().unwrap().x,
        "job (seed 1) is bit-identical in either batch"
    );
    assert_eq!(big[1].as_ref().unwrap().x, small[1].as_ref().unwrap().x);
    assert_eq!(
        big[0].as_ref().unwrap().x,
        solo[0].as_ref().unwrap().x,
        "singleton batches take the amortized path too"
    );
}

#[test]
fn invalid_job_fails_alone_not_its_batch_siblings() {
    let (phi, y, x_true) = planted(64, 128, 4, 15);
    let opts = SolveOptions::default();
    let good = |seed: u64| SolveRequest {
        problem: Problem::new(phi.clone(), y.clone(), 4),
        solver: SolverKind::qniht_fixed(8, 8),
        seed,
    };
    let bad = SolveRequest {
        problem: Problem::new(phi.clone(), vec![0.0; 3], 4), // wrong y length
        solver: SolverKind::qniht_fixed(8, 8),
        seed: 9,
    };
    let mut reg = EngineRegistry::with_defaults(PathBuf::from("artifacts"));
    let results = reg
        .solve_batch("native-quant", &[good(1), bad, good(2)], &opts, &mut NoopBatchObserver)
        .unwrap();
    assert_eq!(results.len(), 3);
    assert_eq!(
        support_of(&results[0].as_ref().expect("valid job solves").x),
        support_of(&x_true)
    );
    assert!(results[1].is_err(), "malformed job fails individually");
    assert_eq!(
        support_of(&results[2].as_ref().expect("valid job solves").x),
        support_of(&x_true)
    );
}

// ------------------------------------------------------------- observers

#[test]
fn observer_cancels_facade_solve_and_report_notes_it() {
    let (phi, y, _) = planted(64, 128, 5, 10);
    let mut stop_at_3 = |st: &IterStat| {
        if st.iter >= 3 {
            ObserverSignal::Stop
        } else {
            ObserverSignal::Continue
        }
    };
    let report = Recovery::problem(Problem::new(phi, y, 5))
        .solver(SolverKind::Niht)
        .options(SolveOptions::default().with_tol(0.0).with_max_iters(100))
        .observer(&mut stop_at_3)
        .run()
        .unwrap();
    assert!(report.stopped_early);
    assert!(!report.converged);
    assert_eq!(report.iterations, 4);
}

#[test]
fn observer_streams_history_equivalent_stats() {
    let (phi, y, _) = planted(64, 128, 4, 11);
    let mut seen: Vec<IterStat> = Vec::new();
    let mut collect = |st: &IterStat| {
        seen.push(*st);
        ObserverSignal::Continue
    };
    let report = Recovery::problem(Problem::new(phi, y, 4))
        .options(SolveOptions::default().with_track_history(true))
        .observer(&mut collect)
        .run()
        .unwrap();
    assert_eq!(seen.len(), report.history.len());
    for (a, b) in seen.iter().zip(&report.history) {
        assert_eq!(a.iter, b.iter);
        assert_eq!(a.resid_nsq, b.resid_nsq);
        assert_eq!(a.mu, b.mu);
    }
}

// --------------------------------------------------------- pluggability

/// A matrix-free operator: Φ is represented only through its products
/// (here backed by a hidden Mat, but the facade cannot see it).
struct MatrixFree(Mat);

impl MeasurementOp for MatrixFree {
    fn m(&self) -> usize {
        self.0.rows
    }
    fn n(&self) -> usize {
        self.0.cols
    }
    fn apply(&self, x: &[f32]) -> Vec<f32> {
        self.0.matvec(x)
    }
    fn apply_t(&self, r: &[f32]) -> Vec<f32> {
        self.0.matvec_t(r)
    }
}

#[test]
fn matrix_free_operator_solves_via_op_kernel() {
    let (phi, y, x_true) = planted(96, 192, 5, 12);
    let op = Arc::new(MatrixFree(phi.as_ref().clone()));
    let report = Recovery::problem(Problem::with_op(op, y, 5))
        .solver(SolverKind::Niht)
        .run()
        .unwrap();
    assert_eq!(support_of(&report.x), support_of(&x_true));
    assert!(metrics::recovery_error(&report.x, &x_true) < 1e-3);
}

#[test]
fn matrix_free_operator_rejected_by_matrix_bound_solvers() {
    let (phi, y, _) = planted(32, 64, 3, 13);
    let op = Arc::new(MatrixFree(phi.as_ref().clone()));
    let err = Recovery::problem(Problem::with_op(op, y, 3))
        .solver(SolverKind::Cosamp)
        .run()
        .unwrap_err()
        .to_string();
    assert!(err.contains("explicit measurement matrix"), "{err}");
}

/// A custom engine registered at runtime: proves new engines plug in
/// without serving-layer changes.
struct EchoEngine;

impl Engine for EchoEngine {
    fn name(&self) -> &'static str {
        "echo"
    }

    fn solve(
        &mut self,
        req: &SolveRequest,
        _opts: &SolveOptions,
        _observer: &mut dyn IterObserver,
    ) -> anyhow::Result<SolveResult> {
        Ok(SolveResult {
            x: req.problem.y().to_vec(),
            iterations: 1,
            converged: true,
            shrink_events: 0,
            history: vec![],
        })
    }
}

#[test]
fn custom_engine_registers_and_dispatches_by_name() {
    let (phi, y, _) = planted(16, 32, 2, 14);
    let mut reg = EngineRegistry::with_defaults(PathBuf::from("artifacts"));
    reg.register("echo", Box::new(|_: &EngineContext| Box::new(EchoEngine) as Box<dyn Engine>));
    let report = Recovery::problem(Problem::new(phi, y.clone(), 2))
        .engine_named("echo")
        .registry(&mut reg)
        .run()
        .unwrap();
    assert_eq!(report.x, y, "custom engine handled the request");
    assert_eq!(report.engine, "echo");
}
