//! Property tests on solver + quantization invariants (testkit-driven).

use lpcs::algorithms::niht::niht_dense;
use lpcs::algorithms::qniht::{qniht, RequantMode};
use lpcs::algorithms::support::{hard_threshold, support_of, top_s_indices};
use lpcs::algorithms::SolveOptions;
use lpcs::linalg::{self, Mat};
use lpcs::quant::packed::PackedMatrix;
use lpcs::quant::{QuantizedMatrix, Quantizer};
use lpcs::rng::XorShift128Plus;
use lpcs::testkit::forall;

#[test]
fn prop_hard_threshold_invariants() {
    forall("hs-invariants", 1, 120, |rng, _| {
        let n = 1 + rng.below(200);
        let x = rng.gaussian_vec(n);
        let s = rng.below(n + 1);
        let h = hard_threshold(&x, s);
        // (1) at most s nonzeros (exactly s when s <= n and x dense-random).
        assert!(support_of(&h).len() <= s.max(0));
        // (2) kept values are unchanged.
        for i in support_of(&h) {
            assert_eq!(h[i], x[i]);
        }
        // (3) every kept |value| >= every dropped |value|.
        let kept_min = support_of(&h).iter().map(|&i| x[i].abs()).fold(f32::MAX, f32::min);
        for (i, &v) in x.iter().enumerate() {
            if h[i] == 0.0 && s > 0 && support_of(&h).len() == s {
                assert!(v.abs() <= kept_min + 1e-6);
            }
        }
        // (4) idempotence.
        assert_eq!(hard_threshold(&h, s), h);
    });
}

#[test]
fn prop_top_s_sorted_and_unique() {
    forall("top-s-sorted", 3, 120, |rng, _| {
        let n = 1 + rng.below(128);
        let x = rng.gaussian_vec(n);
        let s = rng.below(n + 1);
        let idx = top_s_indices(&x, s);
        assert_eq!(idx.len(), s.min(n));
        assert!(idx.windows(2).all(|w| w[0] < w[1]), "ascending + unique");
        assert!(idx.iter().all(|&i| i < n));
    });
}

#[test]
fn prop_quantize_pack_roundtrip() {
    forall("pack-roundtrip", 5, 60, |rng, _| {
        let m = 1 + rng.below(20);
        let n = 1 + rng.below(40);
        let bits = [2u8, 4, 8][rng.below(3)];
        let a = Mat::from_fn(m, n, |_, _| rng.gaussian_f32());
        let qm = QuantizedMatrix::from_mat(&a, bits, rng);
        let back = PackedMatrix::pack(&qm).unpack();
        assert_eq!(qm.codes, back.codes);
        assert_eq!(qm.scale, back.scale);
        assert_eq!(qm.bits, back.bits);
    });
}

#[test]
fn prop_quantization_error_within_lemma4_spacing() {
    forall("quant-error", 7, 60, |rng, _| {
        let bits = 2 + rng.below(7) as u8;
        let q = Quantizer::new(bits);
        let v = rng.uniform_in(-1.0, 1.0) as f32;
        let dq = q.dequantize_one(q.quantize_one(v, rng.uniform_f32(), 1.0), 1.0);
        // per-element error bounded by the level spacing
        assert!((dq - v).abs() <= 1.0 / q.half() as f32 + 1e-6);
    });
}

#[test]
fn prop_niht_output_always_s_sparse_and_finite() {
    forall("niht-sparse", 9, 12, |rng, _| {
        let m = 24 + rng.below(40);
        let n = 2 * m;
        let s = 1 + rng.below(6);
        let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
        let y = rng.gaussian_vec(m); // arbitrary observation, not planted
        let opts = SolveOptions { max_iters: 30, ..Default::default() };
        let r = niht_dense(&phi, &y, s, &opts);
        assert!(support_of(&r.x).len() <= s);
        assert!(r.x.iter().all(|v| v.is_finite()));
    });
}

#[test]
fn prop_qniht_never_worse_than_trivial_zero_by_much() {
    // The solver's residual must end at or below the zero-solution residual
    // (it starts at x = 0, and NIHT accepts only non-increasing cost).
    forall("qniht-cost", 13, 8, |rng, _| {
        let m = 32 + rng.below(32);
        let n = 2 * m;
        let s = 1 + rng.below(4);
        let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
        let mut x = vec![0.0f32; n];
        for i in rng.choose_k(n, s) {
            x[i] = 1.0 + rng.uniform_f32();
        }
        let y = phi.matvec(&x);
        let bits = [4u8, 8][rng.below(2)];
        let r = qniht(&phi, &y, s, bits, 8, RequantMode::Fixed, rng.next_u64(),
            &SolveOptions { max_iters: 60, ..Default::default() });
        // residual of the solution vs residual of zero (= ||y||)
        let resid = linalg::norm2(&linalg::sub(&y, &phi.matvec(&r.x)));
        assert!(
            resid <= linalg::norm2(&y) * 1.05,
            "solver ended worse than doing nothing: {resid} vs {}",
            linalg::norm2(&y)
        );
    });
}

#[test]
fn prop_recovery_error_improves_with_snr_on_average() {
    // Weak-monotonicity statistical property across the testkit cases.
    let errs_low = std::sync::Mutex::new(Vec::new());
    let errs_high = std::sync::Mutex::new(Vec::new());
    forall("snr-monotone", 17, 6, |rng, case| {
        let (m, n, s) = (64usize, 128usize, 4usize);
        let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
        let mut x = vec![0.0f32; n];
        for i in rng.choose_k(n, s) {
            x[i] = 2.0;
        }
        let clean = phi.matvec(&x);
        for (snr_db, errs) in [(0.0f64, &errs_low), (20.0, &errs_high)] {
            let p = linalg::norm2_sq(&clean) as f64 / 10f64.powf(snr_db / 10.0);
            let sd = (p / m as f64).sqrt() as f32;
            let mut r2 = XorShift128Plus::new(case as u64 * 31 + snr_db as u64);
            let y: Vec<f32> = clean.iter().map(|v| v + sd * r2.gaussian_f32()).collect();
            let rec = niht_dense(&phi, &y, s, &SolveOptions::default());
            errs.lock().unwrap().push(lpcs::metrics::recovery_error(&rec.x, &x));
        }
    });
    let mean = |v: &std::sync::Mutex<Vec<f64>>| {
        let v = v.lock().unwrap();
        v.iter().sum::<f64>() / v.len() as f64
    };
    assert!(
        mean(&errs_high) < mean(&errs_low),
        "high-SNR error {} must beat low-SNR {}",
        mean(&errs_high),
        mean(&errs_low)
    );
}
