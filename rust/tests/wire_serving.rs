//! End-to-end wire-serving conformance on a `testkit::ServiceHarness`
//! (real `RecoveryService` + wire server on an ephemeral port):
//!
//! * every servable `SolverKind` × engine pair — and the matrix-free
//!   `PartialFourier` operator, f32 and low-precision — submitted OVER
//!   THE WIRE streams a monotone `IterStat` sequence ending in exactly
//!   one `Done`, whose result is **bit-identical** to
//!   `Recovery::service_dispatch` (the same conformance bar as
//!   `tests/service_matrix.rs`);
//! * cancel-over-the-wire stops a long job which still completes with
//!   its partial iterate;
//! * a slow subscriber sheds stats oldest-first (observed via
//!   `ProgressSub::dropped` and `ServiceMetrics.progress_dropped`) and
//!   provably never blocks the worker;
//! * a client killed mid-stream drops only its subscription: the job
//!   completes, the disconnect is counted, and harness shutdown proves
//!   no threads leak (strict bounded join).

use lpcs::algorithms::{IterStat, SolveOptions};
use lpcs::config::{EngineKind, ServiceConfig};
use lpcs::coordinator::{JobOutcome, JobSpec, JobState, ProblemHandle, ProgressEvent};
use lpcs::mri::{self, MriConfig, MriProblem};
use lpcs::rng::XorShift128Plus;
use lpcs::solver::{Problem, Recovery, SolverKind};
use lpcs::testkit::ServiceHarness;
use lpcs::wire::{Watch, WatchEvent};
use lpcs::Mat;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn planted(m: usize, n: usize, s: usize, seed: u64) -> (Arc<Mat>, Vec<f32>) {
    let mut rng = XorShift128Plus::new(seed);
    let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
    let mut x = vec![0.0f32; n];
    for i in rng.choose_k(n, s) {
        x[i] = 2.0 * rng.gaussian_f32().signum() + 0.3 * rng.gaussian_f32();
    }
    let y = phi.matvec(&x);
    (Arc::new(phi), y)
}

fn harness(workers: usize) -> ServiceHarness {
    ServiceHarness::start(
        ServiceConfig { workers, queue_capacity: 64, max_batch: 4, ..Default::default() },
        SolveOptions::default(),
    )
}

/// Drain a watch stream asserting the protocol invariants: iteration
/// numbers strictly increase (gaps allowed — drop-oldest), no event
/// follows the terminal one, and exactly one `Done` arrives.
fn collect_stream(watch: Watch<'_>) -> (Vec<IterStat>, JobOutcome) {
    let mut stats: Vec<IterStat> = Vec::new();
    let mut done = None;
    for event in watch {
        match event.expect("stream event") {
            WatchEvent::Queued { .. } => {
                // Positions are only pushed while the job still sits in
                // the queue — strictly before its first iteration.
                assert!(done.is_none() && stats.is_empty(), "Queued after the solve started");
            }
            WatchEvent::Progress(st) => {
                assert!(done.is_none(), "Progress after Done");
                stats.push(st);
            }
            WatchEvent::Done(out) => {
                assert!(done.is_none(), "second Done");
                done = Some(out);
            }
        }
    }
    let done = done.expect("stream must end in exactly one Done");
    for w in stats.windows(2) {
        assert!(
            w[0].iter < w[1].iter,
            "stream monotone in iteration number: {} then {}",
            w[0].iter,
            w[1].iter
        );
    }
    (stats, done)
}

/// The dense servable matrix (same pairs `tests/service_matrix.rs`
/// pins in-process; XLA engines need real PJRT bindings and are covered
/// by their dispatch-error tests).
fn dense_matrix() -> Vec<(SolverKind, EngineKind)> {
    vec![
        (SolverKind::Niht, EngineKind::NativeDense),
        (SolverKind::Iht, EngineKind::NativeDense),
        (SolverKind::Cosamp, EngineKind::NativeDense),
        (SolverKind::Fista { lambda: None, debias: true }, EngineKind::NativeDense),
        (SolverKind::qniht_fixed(2, 8), EngineKind::NativeQuant),
        (SolverKind::qniht_fixed(4, 8), EngineKind::NativeQuant),
        (SolverKind::qniht_fixed(8, 8), EngineKind::NativeQuant),
        (SolverKind::qniht_fixed(2, 8), EngineKind::FpgaModel),
        (SolverKind::qniht_fixed(8, 8), EngineKind::FpgaModel),
    ]
}

#[test]
fn every_solver_kind_served_over_the_wire_matches_the_facade_bit_for_bit() {
    let h = harness(2);
    for (case, (solver, engine)) in dense_matrix().into_iter().enumerate() {
        let (phi, y) = planted(96, 192, 5, 300 + case as u64);
        let seed = 70 + case as u64;

        let direct = Recovery::problem(Problem::new(phi.clone(), y.clone(), 5))
            .solver(solver)
            .engine(engine)
            .seed(seed)
            .service_dispatch()
            .run()
            .unwrap_or_else(|e| panic!("{} on {}: direct: {e:#}", solver.name(), engine.name()));

        let mut client = h.client();
        let id = client
            .submit(
                &JobSpec::builder(ProblemHandle::new(phi), y, 5)
                    .solver(solver)
                    .engine(engine)
                    .seed(seed)
                    .build(),
            )
            .unwrap_or_else(|e| panic!("{} on {}: submit: {e:#}", solver.name(), engine.name()));
        let (_stats, out) = collect_stream(client.watch(id).unwrap());

        assert_eq!(out.state, JobState::Done, "{} on {}: {:?}", solver.name(), engine.name(), out.error);
        let served = out.result.expect("done jobs carry a result");
        assert_eq!(
            served.x,
            direct.x,
            "{} on {}: wire-served x̂ must be bit-identical to the facade",
            solver.name(),
            engine.name()
        );
        assert_eq!(served.iterations, direct.iterations, "{} on {}", solver.name(), engine.name());
        assert_eq!(served.converged, direct.converged, "{} on {}", solver.name(), engine.name());
    }
    h.shutdown();
}

#[test]
fn matrix_free_mri_jobs_served_over_the_wire_match_the_facade_bit_for_bit() {
    // The operator ships by content (mask points), not by Arc: the
    // server reconstructs it and must still run the client's exact math,
    // on the f32 and the low-precision sampling paths.
    let h = harness(2);
    let p = MriProblem::build(&MriConfig { resolution: 16, ..Default::default() }, 5).unwrap();
    for (case, bits) in [None, Some(8u8), Some(2)].into_iter().enumerate() {
        let seed = 90 + case as u64;
        let direct_problem = match bits {
            None => Problem::with_op(p.op.clone(), p.y.clone(), p.s),
            Some(b) => mri::lowprec_problem(p.op.clone(), &p.y, p.s, b, seed),
        };
        let direct = Recovery::problem(direct_problem)
            .solver(SolverKind::Niht)
            .engine(EngineKind::NativeDense)
            .seed(seed)
            .service_dispatch()
            .run()
            .unwrap_or_else(|e| panic!("bits={bits:?}: direct: {e:#}"));

        let handle = match bits {
            None => ProblemHandle::partial_fourier(p.op.clone()),
            Some(b) => ProblemHandle::low_prec_fourier(p.op.clone(), b),
        };
        let mut client = h.client();
        let id = client
            .submit(
                &JobSpec::builder(handle, p.y.clone(), p.s)
                    .engine(EngineKind::NativeDense)
                    .solver(SolverKind::Niht)
                    .seed(seed)
                    .build(),
            )
            .unwrap_or_else(|e| panic!("bits={bits:?}: submit: {e:#}"));
        let (_stats, out) = collect_stream(client.watch(id).unwrap());
        assert_eq!(out.state, JobState::Done, "bits={bits:?}: {:?}", out.error);
        let served = out.result.unwrap();
        assert_eq!(served.x, direct.x, "bits={bits:?}: wire-served x̂ ≠ facade x̂");
        assert_eq!(served.iterations, direct.iterations, "bits={bits:?}");
    }
    h.shutdown();
}

#[test]
fn cancel_over_the_wire_stops_the_job_which_still_completes() {
    let h = ServiceHarness::start(
        ServiceConfig { workers: 1, queue_capacity: 8, max_batch: 1, max_wait_ms: 0, ..Default::default() },
        // tol 0 + huge budget: without cancellation this grinds 200k
        // iterations of two 512×4096 matvecs each.
        SolveOptions::default().with_tol(0.0).with_max_iters(200_000),
    );
    let (phi, y) = planted(512, 4096, 8, 21);
    let spec = JobSpec::builder(ProblemHandle::new(phi), y, 8)
        .engine(EngineKind::NativeDense)
        .seed(1)
        .build();
    let mut watcher = h.client();
    let mut canceller = h.client();
    // Cancelling an unknown job is a clean `false`, not an error.
    assert!(!canceller.cancel(424_242).unwrap());

    let id = watcher.submit(&spec).unwrap();
    let mut watch = watcher.watch(id).unwrap();
    // Let the stream prove the job is iterating, then cancel from a
    // second connection.
    let mut seen = 0;
    while seen < 2 {
        match watch.next().expect("job must not finish on its own").unwrap() {
            WatchEvent::Queued { .. } => {}
            WatchEvent::Progress(_) => seen += 1,
            WatchEvent::Done(out) => panic!("finished before cancel: {out:?}"),
        }
    }
    assert!(canceller.cancel(id).unwrap(), "running job accepts cancellation");
    // The stream still ends in exactly one Done, carrying the partial
    // iterate of a non-converged solve.
    let mut done = None;
    for event in watch {
        if let WatchEvent::Done(out) = event.unwrap() {
            done = Some(out);
        }
    }
    let out = done.expect("cancelled stream ends in Done");
    assert_eq!(out.state, JobState::Done);
    let res = out.result.unwrap();
    assert!(!res.converged, "cancelled solve reports non-convergence");
    assert!(res.iterations < 10_000, "stopped early, ran {}", res.iterations);
    assert_eq!(h.service().metrics().cancelled.load(Ordering::Relaxed), 1);
    h.shutdown();
}

#[test]
fn slow_subscriber_sheds_oldest_and_never_blocks_the_worker() {
    // Subscriber queues two deep: a consumer that never drains MUST shed
    // (drop-oldest) instead of stalling the producing worker. The
    // problem is big enough (ms-scale iterations, hundreds of them at
    // tol 0) that the subscription always lands while the solve runs.
    let h = ServiceHarness::start_with_depth(
        ServiceConfig { workers: 1, queue_capacity: 8, max_batch: 1, max_wait_ms: 0, ..Default::default() },
        SolveOptions::default().with_tol(0.0).with_max_iters(300),
        2,
    );
    let (phi, y) = planted(512, 4096, 8, 31);
    let spec = JobSpec::builder(ProblemHandle::new(phi), y, 8)
        .engine(EngineKind::NativeDense)
        .seed(2)
        .build();

    // In-process slow subscriber: registered, then never drained while
    // the job runs out its whole budget.
    let mut client = h.client();
    let id = client.submit(&spec).unwrap();
    let sub = h.service().subscribe(id, 2).expect("known job");
    let out = h
        .service()
        .wait(id, Duration::from_secs(120))
        .expect("worker completes while the subscriber sleeps — it was never blocked");
    assert_eq!(out.state, JobState::Done);
    let total_iters = out.result.as_ref().unwrap().iterations;
    assert!(total_iters > 10, "tol 0 keeps a 512×4096 solve iterating: {total_iters}");

    // Drop-oldest observed: (almost) everything was shed, the queue
    // holds only the freshest stats, in order, then the terminal event.
    assert!(sub.dropped() > 0, "a depth-2 queue under {total_iters} stats must shed");
    let mut kept: Vec<usize> = Vec::new();
    loop {
        match sub.recv(Duration::from_secs(5)) {
            Some(ProgressEvent::Stat(st)) => kept.push(st.iter),
            Some(ProgressEvent::Terminal(t)) => {
                assert_eq!(t.state, JobState::Done);
                break;
            }
            None => panic!("terminal must be delivered"),
        }
    }
    assert!(!kept.is_empty() && kept.len() <= 2, "bounded queue: {kept:?}");
    assert!(kept.windows(2).all(|w| w[0] < w[1]), "shedding preserves order: {kept:?}");
    assert_eq!(
        *kept.last().unwrap(),
        total_iters - 1,
        "drop-oldest keeps the freshest stat"
    );
    assert!(
        h.service().metrics().progress_dropped.load(Ordering::Relaxed) > 0,
        "the service counts shed stats"
    );

    // Over the wire: a client that sleeps mid-stream still gets a
    // coherent (monotone, single-Done) stream for a second job.
    let id2 = client.submit(&spec).unwrap();
    let watch = client.watch(id2).unwrap();
    std::thread::sleep(Duration::from_millis(300));
    let (_stats, out2) = collect_stream(watch);
    assert_eq!(out2.state, JobState::Done);
    h.shutdown();
}

#[test]
fn client_killed_mid_stream_drops_subscription_but_job_completes() {
    let h = ServiceHarness::start(
        ServiceConfig { workers: 1, queue_capacity: 8, max_batch: 1, max_wait_ms: 0, ..Default::default() },
        SolveOptions::default().with_tol(0.0).with_max_iters(150_000),
    );
    // ~1M flops per iteration: hundreds of milliseconds of streaming
    // remain after the client dies, so the relay reliably hits the dead
    // socket while the job is still running.
    let (phi, y) = planted(256, 2048, 4, 41);
    let spec = JobSpec::builder(ProblemHandle::new(phi), y, 4)
        .engine(EngineKind::NativeDense)
        .seed(3)
        .build();
    let id = {
        let mut client = h.client();
        let id = client.submit(&spec).unwrap();
        let mut watch = client.watch(id).unwrap();
        // The stream is live...
        let mut seen = 0;
        while seen < 2 {
            match watch.next().unwrap().unwrap() {
                WatchEvent::Queued { .. } => {}
                WatchEvent::Progress(_) => seen += 1,
                WatchEvent::Done(out) => panic!("finished prematurely: {out:?}"),
            }
        }
        id
        // ...and the client dies here (socket closed mid-stream).
    };
    // The server notices on its next writes, detaches the subscription
    // and counts the disconnect — while the job keeps running.
    let deadline = Instant::now() + Duration::from_secs(30);
    while h.service().metrics().disconnects.load(Ordering::Relaxed) == 0 {
        assert!(Instant::now() < deadline, "disconnect must be detected and counted");
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_ne!(
        h.service().state_of(id),
        None,
        "sanity: the job is still known to the service"
    );
    // Finish fast (the point is the job SURVIVES the dead client, not
    // that we burn 150k iterations) and confirm completion.
    assert!(h.service().cancel(id));
    let out = h.service().wait(id, Duration::from_secs(120)).expect("job completes");
    assert_eq!(out.state, JobState::Done, "{:?}", out.error);
    assert_eq!(h.service().metrics().disconnects.load(Ordering::Relaxed), 1);
    // Strict shutdown: joins the accept thread and every connection
    // handler; panics if any thread (and its service Arc) leaked.
    h.shutdown();
}

#[test]
fn queue_position_streams_while_a_job_waits() {
    // One worker, batch size 1: the second job must sit queued while the
    // first runs, and its watcher must see `QueuePos` pushes (satellite
    // of the wire v2 protocol) before the first `Progress`.
    let h = ServiceHarness::start(
        ServiceConfig { workers: 1, queue_capacity: 8, max_batch: 1, max_wait_ms: 0, ..Default::default() },
        SolveOptions::default().with_tol(0.0).with_max_iters(800),
    );
    let (phi, y) = planted(256, 2048, 4, 61);
    let spec = JobSpec::builder(ProblemHandle::new(phi), y, 4)
        .engine(EngineKind::NativeDense)
        .seed(5)
        .build();
    let mut blocker = h.client();
    blocker.submit(&spec).unwrap(); // occupies the only worker
    let mut client = h.client();
    let id = client.submit(&spec).unwrap();

    let mut queued: Vec<(u64, u64)> = Vec::new();
    let mut progressed = 0usize;
    let mut done = None;
    for event in client.watch(id).unwrap() {
        match event.unwrap() {
            WatchEvent::Queued { position, depth } => {
                assert!(done.is_none() && progressed == 0, "Queued only before the solve");
                assert!(position < depth, "position {position} out of depth {depth}");
                queued.push((position, depth));
            }
            WatchEvent::Progress(_) => progressed += 1,
            WatchEvent::Done(out) => done = Some(out),
        }
    }
    let out = done.expect("stream ends in Done");
    assert_eq!(out.state, JobState::Done, "{:?}", out.error);
    assert!(progressed > 0, "the queued job eventually runs and streams");
    assert!(
        !queued.is_empty(),
        "a job stuck behind a ~1 s solve must surface at least one queue position"
    );
    assert!(
        queued.windows(2).all(|w| w[0].0 >= w[1].0),
        "positions never move backwards: {queued:?}"
    );
    h.shutdown();
}

#[test]
fn bad_subscriptions_error_and_the_connection_stays_usable() {
    let h = harness(1);
    let mut client = h.client();
    // Unknown job: the watch yields exactly one Err and ends.
    let events: Vec<_> = client.watch(424_242).unwrap().collect();
    assert_eq!(events.len(), 1);
    let err = events[0].as_ref().unwrap_err().to_string();
    assert!(err.contains("unknown job"), "{err}");
    // The same connection still serves requests...
    let snapshot = client.metrics().unwrap();
    assert!(snapshot.contains("submitted="), "{snapshot}");
    // ...including a full submit → watch → re-watch cycle: subscribing
    // to an already-terminal job yields its Done immediately.
    let (phi, y) = planted(32, 64, 3, 51);
    let id = client
        .submit(
            &JobSpec::builder(ProblemHandle::new(phi), y, 3)
                .engine(EngineKind::NativeDense)
                .seed(4)
                .build(),
        )
        .unwrap();
    let (_stats, out) = collect_stream(client.watch(id).unwrap());
    assert_eq!(out.state, JobState::Done);
    let (late_stats, late_out) = collect_stream(client.watch(id).unwrap());
    assert!(late_stats.is_empty(), "terminal subscription carries no stats");
    assert_eq!(late_out.state, JobState::Done);
    assert_eq!(late_out.result.unwrap().x, out.result.unwrap().x);
    let snapshot = client.metrics().unwrap();
    assert!(snapshot.contains("completed="), "{snapshot}");
    h.shutdown();
}
