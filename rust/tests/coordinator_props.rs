//! Property tests on the coordinator invariants (testkit-driven; the
//! offline environment vendors no proptest — see DESIGN.md §6).

use lpcs::config::EngineKind;
use lpcs::coordinator::batcher::{form_batches, Batch};
use lpcs::coordinator::job::{BatchKey, JobSpec, JobState, ProblemHandle};
use lpcs::coordinator::queue::{BoundedQueue, Priority, PushError};
use lpcs::coordinator::sched::{schedule, CostModel, QueuedJob, SchedConfig};
use lpcs::linalg::Mat;
use lpcs::rng::XorShift128Plus;
use lpcs::testkit::forall;
use std::sync::Arc;
use std::time::Duration;

fn random_spec(rng: &mut XorShift128Plus, mats: &[Arc<Mat>]) -> JobSpec {
    let phi = mats[rng.below(mats.len())].clone();
    let bits = [2u8, 4, 8][rng.below(3)];
    let engine =
        [EngineKind::NativeQuant, EngineKind::NativeDense, EngineKind::FpgaModel][rng.below(3)];
    let seed = rng.next_u64();
    JobSpec::builder(ProblemHandle::new(phi.clone()), vec![0.0; phi.rows], 1 + rng.below(4))
        .bits(bits, 8)
        .engine(engine)
        .seed(seed)
        .build()
}

#[test]
fn prop_batches_partition_and_preserve_order() {
    forall("batch-partition", 11, 60, |rng, _| {
        let mats: Vec<Arc<Mat>> = (0..3).map(|_| Arc::new(Mat::zeros(4, 8))).collect();
        let n = rng.below(40);
        let jobs: Vec<(u64, JobSpec)> =
            (0..n as u64).map(|id| (id, random_spec(rng, &mats))).collect();
        let max_batch = 1 + rng.below(6);
        let batches = form_batches(jobs.clone(), max_batch);
        // (1) partition: every job appears exactly once, in order.
        let flat: Vec<u64> =
            batches.iter().flat_map(|b| b.jobs.iter().map(|(i, _)| *i)).collect();
        let want: Vec<u64> = (0..n as u64).collect();
        assert_eq!(flat, want);
        // (2) homogeneity + size cap.
        for b in &batches {
            assert!(b.len() >= 1 && b.len() <= max_batch);
            for (_, s) in &b.jobs {
                assert_eq!(s.batch_key(), b.key);
            }
        }
    });
}

#[test]
fn prop_batches_are_maximal_runs() {
    forall("batch-maximal", 13, 40, |rng, _| {
        let mats: Vec<Arc<Mat>> = (0..2).map(|_| Arc::new(Mat::zeros(2, 4))).collect();
        let jobs: Vec<(u64, JobSpec)> =
            (0..20u64).map(|id| (id, random_spec(rng, &mats))).collect();
        let max_batch = 2 + rng.below(5);
        let batches = form_batches(jobs, max_batch);
        // Two consecutive batches with the same key imply the first hit the
        // size cap (otherwise they would have merged).
        for w in batches.windows(2) {
            if w[0].key == w[1].key {
                assert_eq!(w[0].len(), max_batch);
            }
        }
    });
}

#[test]
fn prop_queue_never_exceeds_capacity_and_conserves() {
    forall("queue-capacity", 17, 30, |rng, _| {
        let cap = 1 + rng.below(8);
        let q = BoundedQueue::new(cap);
        let mut pushed = vec![];
        let mut popped = vec![];
        let mut next = 0u64;
        for _ in 0..rng.below(100) {
            assert!(q.len() <= cap, "queue exceeded capacity");
            if rng.uniform() < 0.6 {
                match q.try_push(next, Priority::Normal) {
                    Ok(()) => {
                        pushed.push(next);
                        next += 1;
                    }
                    Err(PushError::Full(_)) => assert_eq!(q.len(), cap),
                    Err(PushError::Closed(_)) => unreachable!(),
                }
            } else if let Some(v) = q.pop_timeout(Duration::from_millis(1)) {
                popped.push(v);
            }
        }
        while let Some(v) = q.pop_timeout(Duration::from_millis(1)) {
            popped.push(v);
        }
        assert_eq!(pushed, popped, "FIFO conservation");
    });
}

#[test]
fn prop_queue_high_priority_overtakes_normal_only() {
    forall("queue-priority", 19, 30, |rng, _| {
        let q = BoundedQueue::new(64);
        let mut highs = vec![];
        let mut normals = vec![];
        for i in 0..rng.below(50) as i64 {
            if rng.uniform() < 0.3 {
                q.try_push(i, Priority::High).unwrap();
                highs.push(i);
            } else {
                q.try_push(i, Priority::Normal).unwrap();
                normals.push(i);
            }
        }
        let mut got = vec![];
        while let Some(v) = q.pop_timeout(Duration::from_millis(1)) {
            got.push(v);
        }
        let want: Vec<i64> = highs.iter().chain(normals.iter()).cloned().collect();
        assert_eq!(got, want, "all high first, each class FIFO");
    });
}

// ------------------------------------------- cost-aware scheduler (PR 3)

/// An adversarial queue snapshot: random keys (Φ identity × bits ×
/// engine × s), random sizes, random High flags, and ages drawn so that
/// overdue jobs can land anywhere in the window — including AFTER
/// younger jobs of the same key, which is the case that breaks naive
/// priority sorts.
fn random_snapshot(rng: &mut XorShift128Plus, starvation_us: u64) -> Vec<QueuedJob> {
    let mats: Vec<Arc<Mat>> = (0..3).map(|_| Arc::new(Mat::zeros(4, 8))).collect();
    let n = rng.below(40);
    (0..n as u64)
        .map(|id| {
            let age_us = if rng.uniform() < 0.2 {
                starvation_us + rng.below(1_000_000) as u64
            } else {
                rng.below(starvation_us.max(1) as usize) as u64
            };
            let high = rng.uniform() < 0.1;
            QueuedJob { id, spec: random_spec(rng, &mats), age_us, high }
        })
        .collect()
}

fn dispatch_ids(batches: &[Batch]) -> Vec<u64> {
    batches.iter().flat_map(|b| b.jobs.iter().map(|(i, _)| *i)).collect()
}

#[test]
fn prop_sched_dispatches_every_job_exactly_once() {
    forall("sched-exactly-once", 31, 100, |rng, _| {
        let snapshot = random_snapshot(rng, 500_000);
        let n = snapshot.len() as u64;
        let cfg = SchedConfig { max_batch: 1 + rng.below(6), starvation_us: 500_000 };
        let batches = schedule(snapshot, &cfg, &CostModel::default());
        // Exactly once: the dispatched ids are a permutation of the input.
        let mut flat = dispatch_ids(&batches);
        flat.sort_unstable();
        assert_eq!(flat, (0..n).collect::<Vec<_>>());
        // Batches are key-homogeneous and within the size cap.
        for b in &batches {
            assert!(b.len() >= 1 && b.len() <= cfg.max_batch);
            for (_, s) in &b.jobs {
                assert_eq!(s.batch_key(), b.key);
            }
        }
    });
}

#[test]
fn prop_sched_fairness_no_overtaking_within_key() {
    forall("sched-fairness", 37, 100, |rng, _| {
        let snapshot = random_snapshot(rng, 500_000);
        // Snapshot position by id (ids are assigned in snapshot order).
        let cfg = SchedConfig { max_batch: 1 + rng.below(6), starvation_us: 500_000 };
        let keys: Vec<(u64, BatchKey)> =
            snapshot.iter().map(|j| (j.id, j.spec.batch_key())).collect();
        let batches = schedule(snapshot, &cfg, &CostModel::default());
        let order = dispatch_ids(&batches);
        // For every key: the ids dispatched under that key must appear in
        // ascending snapshot order — no job is overtaken by a later job
        // with the same BatchKey.
        let mut distinct: Vec<BatchKey> = Vec::new();
        for (_, k) in &keys {
            if !distinct.contains(k) {
                distinct.push(*k);
            }
        }
        for key in distinct {
            let seq: Vec<u64> = order
                .iter()
                .copied()
                .filter(|id| keys.iter().any(|(i, k)| i == id && *k == key))
                .collect();
            assert!(seq.windows(2).all(|w| w[0] < w[1]), "key {key:?} inverted: {seq:?}");
        }
    });
}

#[test]
fn prop_sched_starvation_and_priority_bound_holds() {
    const BOUND: u64 = 500_000;
    forall("sched-starvation", 41, 100, |rng, _| {
        let snapshot = random_snapshot(rng, BOUND);
        let urgent_by_id: Vec<(u64, bool)> =
            snapshot.iter().map(|j| (j.id, j.high || j.age_us >= BOUND)).collect();
        let cfg = SchedConfig { max_batch: 1 + rng.below(6), starvation_us: BOUND };
        let batches = schedule(snapshot, &cfg, &CostModel::default());
        let is_urgent = |id: u64| urgent_by_id.iter().any(|(i, u)| *i == id && *u);
        // A batch is urgent-marked iff it contains an urgent job (High
        // priority or overdue) or a later batch of its key does (the
        // fairness promotion). Every urgent-marked batch must precede
        // every unmarked batch: neither a starving job nor a High job
        // ever loses to a merely cheaper batch.
        let marked: Vec<bool> = batches
            .iter()
            .enumerate()
            .map(|(i, b)| {
                batches[i..]
                    .iter()
                    .filter(|later| later.key == b.key)
                    .any(|later| later.jobs.iter().any(|(id, _)| is_urgent(*id)))
            })
            .collect();
        if let Some(first_unmarked) = marked.iter().position(|m| !m) {
            assert!(
                marked[first_unmarked..].iter().all(|m| !m),
                "an urgent batch was dispatched after a non-urgent one: {marked:?}"
            );
        }
    });
}

#[test]
fn prop_sched_deterministic_for_fixed_seed() {
    forall("sched-determinism", 43, 100, |rng, _| {
        // The snapshot from a fixed case seed is deterministic, and
        // `schedule` is a pure function: two runs over the same snapshot
        // must agree batch-for-batch, job-for-job.
        let snapshot = random_snapshot(rng, 500_000);
        let cfg = SchedConfig { max_batch: 1 + rng.below(6), starvation_us: 500_000 };
        let a = schedule(snapshot.clone(), &cfg, &CostModel::default());
        let b = schedule(snapshot, &cfg, &CostModel::default());
        assert_eq!(a.len(), b.len());
        for (ba, bb) in a.iter().zip(&b) {
            assert_eq!(ba.key, bb.key);
            assert_eq!(
                ba.jobs.iter().map(|(i, _)| *i).collect::<Vec<_>>(),
                bb.jobs.iter().map(|(i, _)| *i).collect::<Vec<_>>()
            );
        }
    });
}

#[test]
fn prop_job_state_machine_legality() {
    forall("job-states", 23, 100, |rng, _| {
        use JobState::*;
        let all = [Queued, Running, Done, Failed];
        let a = all[rng.below(4)];
        let b = all[rng.below(4)];
        let legal = matches!((a, b), (Queued, Running) | (Queued, Failed) | (Running, Done) | (Running, Failed));
        assert_eq!(a.can_transition(b), legal, "{a:?} -> {b:?}");
    });
}

#[test]
fn prop_drain_matching_preserves_fifo_of_rest() {
    forall("drain-fifo", 29, 40, |rng, _| {
        let q = BoundedQueue::new(128);
        let vals: Vec<u32> = (0..rng.below(60) as u32).map(|_| rng.below(10) as u32).collect();
        for &v in &vals {
            q.try_push(v, Priority::Normal).unwrap();
        }
        let drained = q.drain_matching(rng.below(10) + 1, |v| v % 2 == 0);
        // Drained items form a prefix of the queue content.
        assert!(drained.len() <= vals.len());
        for (d, v) in drained.iter().zip(&vals) {
            assert_eq!(d, v);
        }
        // Remaining items come out in original relative order.
        let mut rest = vec![];
        while let Some(v) = q.pop_timeout(Duration::from_millis(1)) {
            rest.push(v);
        }
        assert_eq!(rest, vals[drained.len()..].to_vec());
    });
}
