//! Property tests on the coordinator invariants (testkit-driven; the
//! offline environment vendors no proptest — see DESIGN.md §6).

use lpcs::config::EngineKind;
use lpcs::coordinator::batcher::form_batches;
use lpcs::coordinator::job::{JobSpec, JobState, ProblemHandle};
use lpcs::coordinator::queue::{BoundedQueue, Priority, PushError};
use lpcs::linalg::Mat;
use lpcs::rng::XorShift128Plus;
use lpcs::testkit::forall;
use std::sync::Arc;
use std::time::Duration;

fn random_spec(rng: &mut XorShift128Plus, mats: &[Arc<Mat>]) -> JobSpec {
    let phi = mats[rng.below(mats.len())].clone();
    JobSpec {
        y: vec![0.0; phi.rows],
        s: 1 + rng.below(4),
        bits_phi: [2u8, 4, 8][rng.below(3)],
        bits_y: 8,
        engine: [EngineKind::NativeQuant, EngineKind::NativeDense][rng.below(2)],
        seed: rng.next_u64(),
        problem: ProblemHandle::new(phi),
    }
}

#[test]
fn prop_batches_partition_and_preserve_order() {
    forall("batch-partition", 11, 60, |rng, _| {
        let mats: Vec<Arc<Mat>> = (0..3).map(|_| Arc::new(Mat::zeros(4, 8))).collect();
        let n = rng.below(40);
        let jobs: Vec<(u64, JobSpec)> =
            (0..n as u64).map(|id| (id, random_spec(rng, &mats))).collect();
        let max_batch = 1 + rng.below(6);
        let batches = form_batches(jobs.clone(), max_batch);
        // (1) partition: every job appears exactly once, in order.
        let flat: Vec<u64> =
            batches.iter().flat_map(|b| b.jobs.iter().map(|(i, _)| *i)).collect();
        let want: Vec<u64> = (0..n as u64).collect();
        assert_eq!(flat, want);
        // (2) homogeneity + size cap.
        for b in &batches {
            assert!(b.len() >= 1 && b.len() <= max_batch);
            for (_, s) in &b.jobs {
                assert_eq!(s.batch_key(), b.key);
            }
        }
    });
}

#[test]
fn prop_batches_are_maximal_runs() {
    forall("batch-maximal", 13, 40, |rng, _| {
        let mats: Vec<Arc<Mat>> = (0..2).map(|_| Arc::new(Mat::zeros(2, 4))).collect();
        let jobs: Vec<(u64, JobSpec)> =
            (0..20u64).map(|id| (id, random_spec(rng, &mats))).collect();
        let max_batch = 2 + rng.below(5);
        let batches = form_batches(jobs, max_batch);
        // Two consecutive batches with the same key imply the first hit the
        // size cap (otherwise they would have merged).
        for w in batches.windows(2) {
            if w[0].key == w[1].key {
                assert_eq!(w[0].len(), max_batch);
            }
        }
    });
}

#[test]
fn prop_queue_never_exceeds_capacity_and_conserves() {
    forall("queue-capacity", 17, 30, |rng, _| {
        let cap = 1 + rng.below(8);
        let q = BoundedQueue::new(cap);
        let mut pushed = vec![];
        let mut popped = vec![];
        let mut next = 0u64;
        for _ in 0..rng.below(100) {
            assert!(q.len() <= cap, "queue exceeded capacity");
            if rng.uniform() < 0.6 {
                match q.try_push(next, Priority::Normal) {
                    Ok(()) => {
                        pushed.push(next);
                        next += 1;
                    }
                    Err(PushError::Full(_)) => assert_eq!(q.len(), cap),
                    Err(PushError::Closed(_)) => unreachable!(),
                }
            } else if let Some(v) = q.pop_timeout(Duration::from_millis(1)) {
                popped.push(v);
            }
        }
        while let Some(v) = q.pop_timeout(Duration::from_millis(1)) {
            popped.push(v);
        }
        assert_eq!(pushed, popped, "FIFO conservation");
    });
}

#[test]
fn prop_queue_high_priority_overtakes_normal_only() {
    forall("queue-priority", 19, 30, |rng, _| {
        let q = BoundedQueue::new(64);
        let mut highs = vec![];
        let mut normals = vec![];
        for i in 0..rng.below(50) as i64 {
            if rng.uniform() < 0.3 {
                q.try_push(i, Priority::High).unwrap();
                highs.push(i);
            } else {
                q.try_push(i, Priority::Normal).unwrap();
                normals.push(i);
            }
        }
        let mut got = vec![];
        while let Some(v) = q.pop_timeout(Duration::from_millis(1)) {
            got.push(v);
        }
        let want: Vec<i64> = highs.iter().chain(normals.iter()).cloned().collect();
        assert_eq!(got, want, "all high first, each class FIFO");
    });
}

#[test]
fn prop_job_state_machine_legality() {
    forall("job-states", 23, 100, |rng, _| {
        use JobState::*;
        let all = [Queued, Running, Done, Failed];
        let a = all[rng.below(4)];
        let b = all[rng.below(4)];
        let legal = matches!((a, b), (Queued, Running) | (Queued, Failed) | (Running, Done) | (Running, Failed));
        assert_eq!(a.can_transition(b), legal, "{a:?} -> {b:?}");
    });
}

#[test]
fn prop_drain_matching_preserves_fifo_of_rest() {
    forall("drain-fifo", 29, 40, |rng, _| {
        let q = BoundedQueue::new(128);
        let vals: Vec<u32> = (0..rng.below(60) as u32).map(|_| rng.below(10) as u32).collect();
        for &v in &vals {
            q.try_push(v, Priority::Normal).unwrap();
        }
        let drained = q.drain_matching(rng.below(10) + 1, |v| v % 2 == 0);
        // Drained items form a prefix of the queue content.
        assert!(drained.len() <= vals.len());
        for (d, v) in drained.iter().zip(&vals) {
            assert_eq!(d, v);
        }
        // Remaining items come out in original relative order.
        let mut rest = vec![];
        while let Some(v) = q.pop_timeout(Duration::from_millis(1)) {
            rest.push(v);
        }
        assert_eq!(rest, vals[drained.len()..].to_vec());
    });
}
