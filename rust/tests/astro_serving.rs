//! Telescope serving conformance: matrix-free `OperatorSpec::Visibility`
//! jobs round-trip the wire bit-for-bit against the facade
//! (`Recovery::service_dispatch`) on the f32 and the low-precision
//! sampling paths, streams stay monotone with exactly one `Done`,
//! submit-time validation gates ill-formed stations and wrong
//! solver/engine surfaces, and the physics regressions hold: the
//! matrix-free operator matches its materialized matrix, the full-set
//! noise is conjugate-symmetric at the requested SNR, and 8-bit
//! sampling lands within ~1 dB of f32 on the L=10/r=32 sky.

use lpcs::algorithms::{IterStat, SolveOptions};
use lpcs::config::{EngineKind, ServiceConfig};
use lpcs::coordinator::{JobOutcome, JobSpec, JobState, ProblemHandle};
use lpcs::linalg::norm2_sq;
use lpcs::metrics;
use lpcs::rng::XorShift128Plus;
use lpcs::solver::{MeasurementOp, Problem, Recovery, SolverKind};
use lpcs::telescope::visibility::{self, NoiseShape};
use lpcs::telescope::{op as astro_op, AntennaArray, AstroConfig, ImageGrid, SkyProblem, VisibilityOp};
use lpcs::testkit::ServiceHarness;
use lpcs::wire::{Watch, WatchEvent};
use std::sync::atomic::Ordering;
use std::time::Duration;

fn harness(workers: usize) -> ServiceHarness {
    ServiceHarness::start(
        ServiceConfig { workers, queue_capacity: 64, max_batch: 4, ..Default::default() },
        SolveOptions::default(),
    )
}

fn sky(antennas: usize, resolution: usize, sources: usize, seed: u64) -> SkyProblem {
    let cfg = AstroConfig {
        antennas,
        resolution,
        sources,
        snr_db: 10.0,
        ..Default::default()
    };
    SkyProblem::build(&cfg, seed).unwrap()
}

/// Drain a watch stream asserting the protocol invariants: iteration
/// numbers strictly increase, nothing follows the terminal frame, and
/// exactly one `Done` arrives.
fn collect_stream(watch: Watch<'_>) -> (Vec<IterStat>, JobOutcome) {
    let mut stats: Vec<IterStat> = Vec::new();
    let mut done = None;
    for event in watch {
        match event.expect("stream event") {
            WatchEvent::Queued { .. } => {
                assert!(done.is_none() && stats.is_empty(), "Queued after the solve started");
            }
            WatchEvent::Progress(st) => {
                assert!(done.is_none(), "Progress after Done");
                stats.push(st);
            }
            WatchEvent::Done(out) => {
                assert!(done.is_none(), "second Done");
                done = Some(out);
            }
        }
    }
    let done = done.expect("stream must end in exactly one Done");
    for w in stats.windows(2) {
        assert!(w[0].iter < w[1].iter, "monotone stream: {} then {}", w[0].iter, w[1].iter);
    }
    (stats, done)
}

#[test]
fn visibility_jobs_served_over_the_wire_match_the_facade_bit_for_bit() {
    // The operator ships by content (station positions + grid + freq),
    // not by Arc: the server reconstructs it and must still run the
    // client's exact math — f32 and both quantized widths.
    let h = harness(2);
    let p = sky(5, 12, 4, 6);
    for (case, bits) in [None, Some(8u8), Some(2)].into_iter().enumerate() {
        let seed = 120 + case as u64;
        let direct_problem = match bits {
            None => Problem::with_op(p.op.clone(), p.y.clone(), p.s),
            Some(b) => astro_op::lowprec_problem(p.op.clone(), &p.y, p.s, b, seed),
        };
        let direct = Recovery::problem(direct_problem)
            .solver(SolverKind::Niht)
            .engine(EngineKind::NativeDense)
            .seed(seed)
            .service_dispatch()
            .run()
            .unwrap_or_else(|e| panic!("bits={bits:?}: direct: {e:#}"));

        let handle = match bits {
            None => ProblemHandle::visibility(p.op.clone()),
            Some(b) => ProblemHandle::low_prec_visibility(p.op.clone(), b),
        };
        let mut client = h.client();
        let id = client
            .submit(
                &JobSpec::builder(handle, p.y.clone(), p.s)
                    .engine(EngineKind::NativeDense)
                    .solver(SolverKind::Niht)
                    .seed(seed)
                    .build(),
            )
            .unwrap_or_else(|e| panic!("bits={bits:?}: submit: {e:#}"));
        let (_stats, out) = collect_stream(client.watch(id).unwrap());
        assert_eq!(out.state, JobState::Done, "bits={bits:?}: {:?}", out.error);
        let served = out.result.unwrap();
        assert_eq!(served.x, direct.x, "bits={bits:?}: wire-served x̂ ≠ facade x̂");
        assert_eq!(served.iterations, direct.iterations, "bits={bits:?}");
        assert_eq!(served.converged, direct.converged, "bits={bits:?}");
    }
    h.shutdown();
}

#[test]
fn invalid_visibility_jobs_rejected_at_submit_and_counted() {
    let h = harness(1);
    let p = sky(4, 8, 3, 7);
    let m = p.m();
    // Wrong solver for the matrix-free surface.
    assert!(h
        .service()
        .submit(
            JobSpec::builder(ProblemHandle::visibility(p.op.clone()), vec![0.0; m], 2)
                .engine(EngineKind::NativeDense)
                .solver(SolverKind::Cosamp)
                .build(),
        )
        .is_err());
    // Wrong engine.
    assert!(h
        .service()
        .submit(
            JobSpec::builder(ProblemHandle::visibility(p.op.clone()), vec![0.0; m], 2)
                .engine(EngineKind::NativeQuant)
                .solver(SolverKind::Niht)
                .build(),
        )
        .is_err());
    // Unpacked bit width.
    assert!(h
        .service()
        .submit(
            JobSpec::builder(ProblemHandle::low_prec_visibility(p.op.clone(), 3), vec![0.0; m], 2)
                .engine(EngineKind::NativeDense)
                .solver(SolverKind::Niht)
                .build(),
        )
        .is_err());
    // Ill-formed station (zero frequency) dies at submit, not in a worker.
    let mut rng = XorShift128Plus::new(1);
    let mut bad_array = AntennaArray::lofar_like(4, 50e6, &mut rng);
    bad_array.freq_hz = 0.0;
    let bad = std::sync::Arc::new(VisibilityOp::new(bad_array, ImageGrid::new(8, 0.4)));
    let bad_m = MeasurementOp::m(&*bad);
    assert!(h
        .service()
        .submit(
            JobSpec::builder(ProblemHandle::visibility(bad), vec![0.0; bad_m], 2)
                .engine(EngineKind::NativeDense)
                .solver(SolverKind::Niht)
                .build(),
        )
        .is_err());
    let metrics = h.service().metrics();
    assert_eq!(metrics.invalid.load(Ordering::Relaxed), 4, "all four counted invalid");
    assert_eq!(metrics.submitted.load(Ordering::Relaxed), 0, "no job id allocated");
    h.shutdown();
}

#[test]
fn shared_visibility_op_jobs_batch_and_all_recover() {
    // Several jobs against ONE shared operator Arc — the telescope
    // snapshot stream. All complete with the operator as batch identity.
    let h = harness(2);
    let p = sky(6, 12, 4, 8);
    let mut ids = Vec::new();
    for k in 0..6u64 {
        let handle = if k % 2 == 0 {
            ProblemHandle::visibility(p.op.clone())
        } else {
            ProblemHandle::low_prec_visibility(p.op.clone(), 8)
        };
        let id = h
            .service()
            .submit(
                JobSpec::builder(handle, p.y.clone(), p.s)
                    .engine(EngineKind::NativeDense)
                    .solver(SolverKind::Niht)
                    .seed(k)
                    .build(),
            )
            .unwrap();
        ids.push(id);
    }
    for id in ids {
        let out = h.service().wait(id, Duration::from_secs(120)).expect("finishes");
        assert_eq!(out.state, JobState::Done, "{:?}", out.error);
        assert_eq!(out.result.unwrap().x.len(), p.n());
    }
    assert_eq!(h.service().metrics().completed.load(Ordering::Relaxed), 6);
    h.shutdown();
}

#[test]
fn matrix_free_operator_matches_its_materialized_matrix() {
    // Integration-level parity: the operator a served job runs and the
    // dense matrix the paper-parity path materializes are the same map.
    let p = sky(6, 16, 4, 9);
    let dense = p.op.to_mat();
    assert_eq!((dense.rows, dense.cols), (p.m(), p.n()));
    let mut rng = XorShift128Plus::new(2);
    let x = rng.gaussian_vec(p.n());
    let free = p.op.apply(&x);
    let mat = dense.matvec(&x);
    for (a, b) in free.iter().zip(&mat) {
        assert!((a - b).abs() <= 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
    }
    let v = rng.gaussian_vec(p.m());
    let free_t = p.op.apply_t(&v);
    let mat_t = dense.matvec_t(&v);
    for (a, b) in free_t.iter().zip(&mat_t) {
        assert!((a - b).abs() <= 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
    }
}

#[test]
fn full_set_noise_is_conjugate_symmetric_and_snr_calibrated() {
    // Regression for the noise bugfix: draws happen only on unique
    // baselines + autocorrelations, conjugates mirror them, and the
    // achieved SNR over the whole stacked vector still hits the target.
    let l = 6;
    let mut rng = XorShift128Plus::new(3);
    let array = AntennaArray::lofar_like(l, 50e6, &mut rng);
    let op = VisibilityOp::with_full_baselines(array, ImageGrid::new(12, 0.4));
    let mb = l * l;
    let mut x = vec![0.0f32; MeasurementOp::n(&op)];
    x[7] = 1.0;
    x[100] = 0.6;
    let clean = op.apply(&x);
    let mut ratios = Vec::new();
    for seed in 0..20 {
        let mut r = rng.fork(seed);
        let (y, _) = visibility::add_noise(&clean, 0.0, &mut r, NoiseShape::Full { antennas: l });
        for i in 0..l {
            assert_eq!(y[mb + i * l + i], clean[mb + i * l + i], "Im(auto) carries no noise");
            for k in (i + 1)..l {
                let (z1, z2) = (i * l + k, k * l + i);
                let (re1, re2) = (y[z1] - clean[z1], y[z2] - clean[z2]);
                let (im1, im2) = (y[mb + z1] - clean[mb + z1], y[mb + z2] - clean[mb + z2]);
                assert!((re1 - re2).abs() < 1e-6, "Re noise mirrored");
                assert!((im1 + im2).abs() < 1e-6, "Im noise conjugated");
            }
        }
        let noise: Vec<f32> = y.iter().zip(&clean).map(|(a, b)| a - b).collect();
        ratios.push((norm2_sq(&clean) / norm2_sq(&noise)) as f64);
    }
    let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
    assert!((10.0 * mean.log10()).abs() < 1.0, "achieved snr = {}", 10.0 * mean.log10());
}

#[test]
fn eight_bit_recovery_within_one_db_of_f32_on_the_l10_r32_sky() {
    // The acceptance pin: on the bench-scale sky (L=10 antennas, 32×32
    // grid) the 8-bit sampling path reconstructs within ~1 dB of the
    // f32 matrix-free baseline.
    let p = sky(10, 32, 12, 1);
    let f32_rep = Recovery::problem(Problem::with_op(p.op.clone(), p.y.clone(), p.s))
        .solver(SolverKind::Niht)
        .run()
        .unwrap();
    let psnr_f32 = metrics::psnr(&f32_rep.x, &p.x_true);

    let q8_rep = Recovery::problem(astro_op::lowprec_problem(p.op.clone(), &p.y, p.s, 8, 1))
        .solver(SolverKind::Niht)
        .seed(1)
        .run()
        .unwrap();
    let psnr_q8 = metrics::psnr(&q8_rep.x, &p.x_true);

    assert!(psnr_f32 > 15.0, "f32 baseline must reconstruct the sky at all: {psnr_f32:.2} dB");
    assert!(
        psnr_q8 >= psnr_f32 - 1.5,
        "8-bit sampling path within ~1 dB of f32: {psnr_q8:.2} vs {psnr_f32:.2} dB"
    );
}
