//! End-to-end routed-serving conformance on a `testkit::RouterHarness`
//! (real backends + a real `RouterServer`, all on ephemeral ports):
//!
//! * every servable dense `SolverKind` × engine pair — and matrix-free
//!   MRI, f32 and low-precision — submitted THROUGH THE ROUTER over two
//!   backends is **bit-identical** to `Recovery::service_dispatch` (the
//!   same bar `tests/wire_serving.rs` pins for a single server);
//! * batch affinity is provable: jobs sharing a `route_key` (same Φ,
//!   solver, engine, sparsity — differing seeds) all land on ONE
//!   backend and actually batch there;
//! * a watch stream survives the owning backend dying mid-solve: the
//!   router resubmits to the survivor and the client sees one strictly
//!   monotone stream ending in exactly one `Done`;
//! * admission control rejects with typed [`ErrCode::QueueFull`] — both
//!   when the router's in-flight table saturates and when a probed
//!   backend queue crosses `queue_limit` — and never buffers the job;
//! * the consistent-hash ring is deterministic and minimally disruptive
//!   under membership change (property-tested over random fleets).

use lpcs::algorithms::{IterStat, SolveOptions};
use lpcs::config::{EngineKind, ServiceConfig};
use lpcs::coordinator::{JobOutcome, JobSpec, JobState, ProblemHandle};
use lpcs::mri::{self, MriConfig, MriProblem};
use lpcs::rng::XorShift128Plus;
use lpcs::router::HashRing;
use lpcs::solver::{Problem, Recovery, SolverKind};
use lpcs::testkit::{self, RouterHarness};
use lpcs::wire::{ErrCode, Watch, WatchEvent};
use lpcs::Mat;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn planted(m: usize, n: usize, s: usize, seed: u64) -> (Arc<Mat>, Vec<f32>) {
    let mut rng = XorShift128Plus::new(seed);
    let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
    let mut x = vec![0.0f32; n];
    for i in rng.choose_k(n, s) {
        x[i] = 2.0 * rng.gaussian_f32().signum() + 0.3 * rng.gaussian_f32();
    }
    let y = phi.matvec(&x);
    (Arc::new(phi), y)
}

/// Drain a watch stream asserting the protocol invariants — identical
/// discipline to `tests/wire_serving.rs`, now applied across a router
/// hop: strictly increasing iterations, `Queued` only before the solve,
/// exactly one `Done`.
fn collect_stream(watch: Watch<'_>) -> (Vec<IterStat>, JobOutcome) {
    let mut stats: Vec<IterStat> = Vec::new();
    let mut done = None;
    for event in watch {
        match event.expect("stream event") {
            WatchEvent::Queued { .. } => {
                assert!(done.is_none() && stats.is_empty(), "Queued after the solve started");
            }
            WatchEvent::Progress(st) => {
                assert!(done.is_none(), "Progress after Done");
                stats.push(st);
            }
            WatchEvent::Done(out) => {
                assert!(done.is_none(), "second Done");
                done = Some(out);
            }
        }
    }
    let done = done.expect("stream must end in exactly one Done");
    for w in stats.windows(2) {
        assert!(w[0].iter < w[1].iter, "monotone stream: {} then {}", w[0].iter, w[1].iter);
    }
    (stats, done)
}

/// The dense servable matrix (the pairs `tests/service_matrix.rs` and
/// `tests/wire_serving.rs` pin; XLA engines need real PJRT bindings).
fn dense_matrix() -> Vec<(SolverKind, EngineKind)> {
    vec![
        (SolverKind::Niht, EngineKind::NativeDense),
        (SolverKind::Iht, EngineKind::NativeDense),
        (SolverKind::Cosamp, EngineKind::NativeDense),
        (SolverKind::Fista { lambda: None, debias: true }, EngineKind::NativeDense),
        (SolverKind::qniht_fixed(2, 8), EngineKind::NativeQuant),
        (SolverKind::qniht_fixed(4, 8), EngineKind::NativeQuant),
        (SolverKind::qniht_fixed(8, 8), EngineKind::NativeQuant),
        (SolverKind::qniht_fixed(2, 8), EngineKind::FpgaModel),
        (SolverKind::qniht_fixed(8, 8), EngineKind::FpgaModel),
    ]
}

#[test]
fn every_dense_pair_routed_over_two_backends_matches_the_facade_bit_for_bit() {
    let h = RouterHarness::start(
        2,
        ServiceConfig { workers: 2, queue_capacity: 64, max_batch: 4, ..Default::default() },
        SolveOptions::default(),
    );
    let cases = dense_matrix();
    let total = cases.len() as u64;
    for (case, (solver, engine)) in cases.into_iter().enumerate() {
        let (phi, y) = planted(96, 192, 5, 500 + case as u64);
        let seed = 80 + case as u64;

        let direct = Recovery::problem(Problem::new(phi.clone(), y.clone(), 5))
            .solver(solver)
            .engine(engine)
            .seed(seed)
            .service_dispatch()
            .run()
            .unwrap_or_else(|e| panic!("{} on {}: direct: {e:#}", solver.name(), engine.name()));

        let mut client = h.client();
        let id = client
            .submit(
                &JobSpec::builder(ProblemHandle::new(phi), y, 5)
                    .solver(solver)
                    .engine(engine)
                    .seed(seed)
                    .build(),
            )
            .unwrap_or_else(|e| panic!("{} on {}: submit: {e}", solver.name(), engine.name()));
        let (_stats, out) = collect_stream(client.watch(id).unwrap());

        assert_eq!(out.state, JobState::Done, "{} on {}: {:?}", solver.name(), engine.name(), out.error);
        let served = out.result.expect("done jobs carry a result");
        assert_eq!(
            served.x,
            direct.x,
            "{} on {}: routed x̂ must be bit-identical to the facade",
            solver.name(),
            engine.name()
        );
        assert_eq!(served.iterations, direct.iterations, "{} on {}", solver.name(), engine.name());
        assert_eq!(served.converged, direct.converged, "{} on {}", solver.name(), engine.name());
    }
    let m = h.router().metrics();
    assert_eq!(m.routed.load(Ordering::Relaxed), total, "every case was placed");
    assert_eq!(
        m.backend(0).routed.load(Ordering::Relaxed) + m.backend(1).routed.load(Ordering::Relaxed),
        total,
        "per-backend counters account for every placement"
    );
    assert_eq!(m.rejected_full.load(Ordering::Relaxed), 0);
    assert_eq!(m.rejected_down.load(Ordering::Relaxed), 0);
    h.shutdown();
}

#[test]
fn matrix_free_mri_jobs_routed_match_the_facade_bit_for_bit() {
    // Operators ship by content (mask points) through BOTH hops —
    // client→router→backend — and the backend must still run the
    // client's exact math, f32 and low-precision alike.
    let h = RouterHarness::start(
        2,
        ServiceConfig { workers: 2, queue_capacity: 64, max_batch: 4, ..Default::default() },
        SolveOptions::default(),
    );
    let p = MriProblem::build(&MriConfig { resolution: 16, ..Default::default() }, 5).unwrap();
    for (case, bits) in [None, Some(8u8), Some(2)].into_iter().enumerate() {
        let seed = 90 + case as u64;
        let direct_problem = match bits {
            None => Problem::with_op(p.op.clone(), p.y.clone(), p.s),
            Some(b) => mri::lowprec_problem(p.op.clone(), &p.y, p.s, b, seed),
        };
        let direct = Recovery::problem(direct_problem)
            .solver(SolverKind::Niht)
            .engine(EngineKind::NativeDense)
            .seed(seed)
            .service_dispatch()
            .run()
            .unwrap_or_else(|e| panic!("bits={bits:?}: direct: {e:#}"));

        let handle = match bits {
            None => ProblemHandle::partial_fourier(p.op.clone()),
            Some(b) => ProblemHandle::low_prec_fourier(p.op.clone(), b),
        };
        let mut client = h.client();
        let id = client
            .submit(
                &JobSpec::builder(handle, p.y.clone(), p.s)
                    .engine(EngineKind::NativeDense)
                    .solver(SolverKind::Niht)
                    .seed(seed)
                    .build(),
            )
            .unwrap_or_else(|e| panic!("bits={bits:?}: submit: {e}"));
        let (_stats, out) = collect_stream(client.watch(id).unwrap());
        assert_eq!(out.state, JobState::Done, "bits={bits:?}: {:?}", out.error);
        let served = out.result.unwrap();
        assert_eq!(served.x, direct.x, "bits={bits:?}: routed x̂ ≠ facade x̂");
        assert_eq!(served.iterations, direct.iterations, "bits={bits:?}");
    }
    h.shutdown();
}

#[test]
fn same_route_key_jobs_land_on_one_backend_and_batch_there() {
    // Twelve jobs sharing Φ/solver/engine/sparsity (only seeds differ —
    // `route_key` excludes seed and y) must all consistent-hash to the
    // SAME backend, where they amortize quantize+pack by batching —
    // the whole point of affinity routing.
    let h = RouterHarness::start(
        2,
        ServiceConfig { workers: 2, queue_capacity: 64, max_batch: 8, ..Default::default() },
        SolveOptions::default().with_tol(0.0).with_max_iters(300),
    );
    let (phi, y) = planted(96, 192, 5, 777);
    let mut client = h.client();
    let ids: Vec<_> = (0..12)
        .map(|k| {
            client
                .submit(
                    &JobSpec::builder(ProblemHandle::new(phi.clone()), y.clone(), 5)
                        .engine(EngineKind::NativeDense)
                        .seed(1000 + k)
                        .build(),
                )
                .expect("routed submit")
        })
        .collect();
    for id in ids {
        let (_stats, out) = collect_stream(client.watch(id).unwrap());
        assert_eq!(out.state, JobState::Done, "{:?}", out.error);
    }

    let m = h.router().metrics();
    let routed: Vec<u64> =
        (0..2).map(|i| m.backend(i).routed.load(Ordering::Relaxed)).collect();
    assert_eq!(routed.iter().sum::<u64>(), 12);
    assert!(
        routed.contains(&12) && routed.contains(&0),
        "same-route_key jobs must all land on one backend, got {routed:?}"
    );
    let owner = routed.iter().position(|&r| r == 12).unwrap();
    let sm = h.backend_service(owner).metrics();
    assert_eq!(sm.submitted.load(Ordering::Relaxed), 12);
    assert_eq!(sm.batched_jobs.load(Ordering::Relaxed), 12);
    let batches = sm.batches.load(Ordering::Relaxed);
    assert!(
        (1..12).contains(&batches),
        "co-routed jobs must share batches: 12 jobs in {batches} batches"
    );
    assert_eq!(
        h.backend_service(1 - owner).metrics().submitted.load(Ordering::Relaxed),
        0,
        "the other backend never sees this key"
    );
    h.shutdown();
}

#[test]
fn watch_stream_survives_a_backend_loss_mid_solve() {
    let mut h = RouterHarness::start(
        2,
        ServiceConfig { workers: 1, queue_capacity: 8, max_batch: 1, max_wait_ms: 0, ..Default::default() },
        // tol 0 + huge budget: the job cannot finish on its own inside
        // the test window — only the relayed cancel ends it.
        SolveOptions::default().with_tol(0.0).with_max_iters(150_000),
    );
    let (phi, y) = planted(256, 2048, 4, 41);
    let spec = JobSpec::builder(ProblemHandle::new(phi), y, 4)
        .engine(EngineKind::NativeDense)
        .seed(9)
        .build();
    let mut client = h.client();
    let id = client.submit(&spec).unwrap();
    let owner = (0..2)
        .find(|&i| h.router().metrics().backend(i).routed.load(Ordering::Relaxed) == 1)
        .expect("exactly one backend owns the job");

    let mut watch = client.watch(id).unwrap();
    let mut iters: Vec<usize> = Vec::new();
    while iters.len() < 2 {
        match watch.next().expect("job must not finish on its own").unwrap() {
            WatchEvent::Queued { .. } => {}
            WatchEvent::Progress(st) => iters.push(st.iter),
            WatchEvent::Done(out) => panic!("finished before the kill: {out:?}"),
        }
    }
    // Partition the owning backend: its wire server dies (connections
    // drop, reconnects refused) while its service — and the now-ghost
    // solve — keeps running, exactly like a machine loss.
    h.kill_backend_server(owner);

    // The relay must detect the loss, resubmit to the survivor and
    // resume the stream (observable as the `resumed` counter).
    let deadline = Instant::now() + Duration::from_secs(30);
    while h.router().metrics().resumed.load(Ordering::Relaxed) == 0 {
        assert!(Instant::now() < deadline, "router must resume the stream onto the survivor");
        std::thread::sleep(Duration::from_millis(20));
    }

    // The resumed job still honors cancel through the router…
    let mut canceller = h.client();
    assert!(canceller.cancel(id).unwrap(), "resumed job accepts cancellation");
    // …and the stream stays monotone to its single Done, which can only
    // come from the survivor: the owner's network face is gone.
    let mut done = None;
    for event in watch {
        match event.unwrap() {
            WatchEvent::Queued { .. } => {}
            WatchEvent::Progress(st) => iters.push(st.iter),
            WatchEvent::Done(out) => {
                assert!(done.is_none(), "second Done");
                done = Some(out);
            }
        }
    }
    let out = done.expect("stream ends in exactly one Done despite the loss");
    assert_eq!(out.state, JobState::Done, "{:?}", out.error);
    assert!(!out.result.unwrap().converged, "cancelled resume reports non-convergence");
    assert!(iters.windows(2).all(|w| w[0] < w[1]), "monotone across the failover: {iters:?}");

    let m = h.router().metrics();
    assert!(m.resumed.load(Ordering::Relaxed) >= 1);
    assert!(m.backend_down_events.load(Ordering::Relaxed) >= 1, "the loss was recorded");
    assert!(m.backend(owner).down_events.load(Ordering::Relaxed) >= 1);
    assert!(
        m.backend(1 - owner).resumed.load(Ordering::Relaxed) >= 1,
        "the survivor hosts the resume"
    );

    // Reap the ghost: the killed backend's service still grinds the
    // original submission (its first job — backend-local id 1).
    assert!(h.backend_service(owner).cancel(1), "ghost job is still running");
    h.backend_service(owner)
        .wait(1, Duration::from_secs(120))
        .expect("ghost completes after cancel");
    h.shutdown();
}

#[test]
fn saturated_inflight_table_rejects_typed_and_drains() {
    let h = RouterHarness::start_with(
        1,
        ServiceConfig { workers: 1, queue_capacity: 8, max_batch: 1, max_wait_ms: 0, ..Default::default() },
        SolveOptions::default().with_tol(0.0).with_max_iters(150_000),
        |c| c.max_inflight = 1,
    );
    let (phi, y) = planted(256, 2048, 4, 51);
    let spec = JobSpec::builder(ProblemHandle::new(phi), y, 4)
        .engine(EngineKind::NativeDense)
        .seed(11)
        .build();
    let mut holder = h.client();
    let id = holder.submit(&spec).unwrap();

    // Table full: the second submit is refused with the TYPED code —
    // never queued router-side, never forwarded.
    let mut second = h.client();
    let err = second.submit(&spec).unwrap_err();
    assert!(err.is(ErrCode::QueueFull), "typed queue-full rejection, got: {err}");
    assert!(err.msg.contains("in-flight"), "{err}");
    assert_eq!(h.router().metrics().rejected_full.load(Ordering::Relaxed), 1);
    assert_eq!(h.router().state().inflight(), 1);
    assert_eq!(
        h.backend_service(0).metrics().submitted.load(Ordering::Relaxed),
        1,
        "the rejected job never reached a backend"
    );

    // The router answers the ops frames on its own behalf: metrics in
    // the service snapshot discipline, StatsReq with table occupancy.
    let snap = second.metrics().unwrap();
    assert!(snap.contains("rejected_full=1"), "{snap}");
    let st = second.stats().unwrap();
    assert_eq!((st.queue_depth, st.queue_capacity, st.workers), (1, 1, 1), "{st:?}");

    // Draining the slot (Done relayed to a watcher) re-opens admission.
    assert!(second.cancel(id).unwrap());
    let (_stats, out) = collect_stream(holder.watch(id).unwrap());
    assert_eq!(out.state, JobState::Done, "{:?}", out.error);
    assert_eq!(h.router().state().inflight(), 0, "Done drains the in-flight table");
    let id2 = second.submit(&spec).expect("admission reopens once the table drains");
    assert!(second.cancel(id2).unwrap());
    let (_stats, out2) = collect_stream(second.watch(id2).unwrap());
    assert_eq!(out2.state, JobState::Done, "{:?}", out2.error);
    h.shutdown();
}

#[test]
fn probed_backend_queue_limit_gates_admission_with_typed_rejection() {
    let h = RouterHarness::start_with(
        1,
        ServiceConfig { workers: 1, queue_capacity: 8, max_batch: 1, max_wait_ms: 0, ..Default::default() },
        SolveOptions::default().with_tol(0.0).with_max_iters(150_000),
        |c| c.queue_limit = 1,
    );
    let (phi, y) = planted(256, 2048, 4, 71);
    let spec = JobSpec::builder(ProblemHandle::new(phi), y, 4)
        .engine(EngineKind::NativeDense)
        .seed(13)
        .build();
    let mut client = h.client();
    let a = client.submit(&spec).unwrap();
    // Let the lone worker take job A so B lands in an empty queue.
    let deadline = Instant::now() + Duration::from_secs(30);
    while h.backend_service(0).queue_depth() > 0 {
        assert!(Instant::now() < deadline, "worker must pick up the first job");
        std::thread::sleep(Duration::from_millis(10));
    }
    // …and let a fresh probe observe the now-empty queue, so B is not
    // bounced off a stale depth sample taken while A still sat queued.
    let deadline = Instant::now() + Duration::from_secs(30);
    while h.router().state().backends[0].queue_depth.load(Ordering::Relaxed) > 0 {
        assert!(Instant::now() < deadline, "a probe must observe the drained queue");
        std::thread::sleep(Duration::from_millis(10));
    }
    let b = client.submit(&spec).unwrap();
    // The health probe carries the backend's queue depth back to the
    // router; once it crosses `queue_limit`, admission closes.
    let deadline = Instant::now() + Duration::from_secs(30);
    while h.router().state().backends[0].queue_depth.load(Ordering::Relaxed) < 1 {
        assert!(Instant::now() < deadline, "a probe must observe the queued job");
        std::thread::sleep(Duration::from_millis(10));
    }
    let err = client.submit(&spec).unwrap_err();
    assert!(err.is(ErrCode::QueueFull), "typed queue-limit rejection, got: {err}");
    assert!(err.msg.contains("queue limit"), "{err}");
    assert!(h.router().metrics().rejected_full.load(Ordering::Relaxed) >= 1);

    // Drain: cancel both jobs (B may still be queued — a queued cancel
    // stops it at its first iteration boundary) and watch them out.
    assert!(client.cancel(a).unwrap());
    let (_stats, oa) = collect_stream(client.watch(a).unwrap());
    assert_eq!(oa.state, JobState::Done, "{:?}", oa.error);
    assert!(client.cancel(b).unwrap());
    let (_stats, ob) = collect_stream(client.watch(b).unwrap());
    assert_eq!(ob.state, JobState::Done, "{:?}", ob.error);
    h.shutdown();
}

#[test]
fn hash_ring_is_deterministic_and_minimally_disruptive() {
    // Over random fleets: (a) the same membership always yields the
    // same placement (what makes affinity stable across router
    // restarts); (b) removing one backend moves ONLY that backend's
    // keys (what keeps a down event from scattering every job's
    // affinity fleet-wide).
    testkit::forall("hash-ring-fleet", 0x51C6_A11, 60, |rng, _| {
        let n = 2 + rng.below(5);
        let vnodes = 1 + rng.below(64);
        let addrs: Vec<String> = (0..n)
            .map(|i| format!("10.{}.{}.{}:7070", rng.below(200), rng.below(200), i))
            .collect();
        let build = |alive: &[usize]| {
            HashRing::build(alive.iter().map(|&i| (i, addrs[i].as_str())), vnodes)
        };
        let all: Vec<usize> = (0..n).collect();
        let ring = build(&all);
        let again = build(&all);
        let gone = rng.below(n);
        let survivors: Vec<usize> = (0..n).filter(|&i| i != gone).collect();
        let shrunk = build(&survivors);
        for _ in 0..256 {
            let key = rng.next_u64();
            let before = ring.route(key).expect("non-empty ring routes every key");
            assert_eq!(again.route(key), Some(before), "same fleet ⇒ same placement");
            let after = shrunk.route(key).expect("survivors still route");
            assert_ne!(after, gone, "a removed backend receives nothing");
            if before != gone {
                assert_eq!(after, before, "removal moves only the dead backend's keys");
            }
        }
    });
}
