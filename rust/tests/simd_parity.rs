//! Backend parity matrix: (backend ∈ {scalar, dispatched}) × (bits ∈
//! {2,4,8}) × ragged n — integer kernels must be bit-identical, f32
//! reductions within 1e-3 relative. Plus pool determinism: `LPCS_THREADS=1`
//! must match the default-parallelism output exactly (all kernels compute
//! each output element independently or in fixed input order, so chunking
//! cannot change the result).

use lpcs::linalg::Mat;
use lpcs::lowprec;
use lpcs::quant::packed::PackedMatrix;
use lpcs::quant::{QuantizedMatrix, Quantizer};
use lpcs::rng::XorShift128Plus;
use lpcs::simd::{self, Backend, Kernels};

const DIMS: [usize; 5] = [64, 65, 127, 256, 300];

fn setup(m: usize, n: usize, bits: u8, seed: u64) -> (QuantizedMatrix, PackedMatrix, Vec<f32>) {
    let mut rng = XorShift128Plus::new(seed);
    let a = Mat::from_fn(m, n, |_, _| rng.gaussian_f32());
    let qm = QuantizedMatrix::from_mat(&a, bits, &mut rng);
    let p = PackedMatrix::pack(&qm);
    let x = rng.gaussian_vec(n);
    (qm, p, x)
}

fn close(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}");
    for (g, w) in got.iter().zip(want) {
        assert!((g - w).abs() <= 1e-3 * (1.0 + w.abs()), "{ctx}: {g} vs {w}");
    }
}

#[test]
fn packed_matvec_backend_matrix() {
    let scalar = simd::by_backend(Backend::Scalar);
    let dispatched = simd::active();
    for bits in [2u8, 4, 8] {
        for n in DIMS {
            let (qm, p, x) = setup(13, n, bits, 1000 + n as u64 + bits as u64);
            let want = lowprec::packed_matvec_with(scalar, &p, &x);
            // Scalar backend vs the unpacked int8 reference.
            let reference = lowprec::qmatvec(&qm.codes, qm.m, qm.n, qm.multiplier(), &x);
            close(&want, &reference, &format!("scalar-vs-ref bits={bits} n={n}"));
            let got = lowprec::packed_matvec_with(dispatched, &p, &x);
            close(&got, &want, &format!("dispatched bits={bits} n={n}"));
        }
    }
}

#[test]
fn packed_matvec_q8_backend_matrix_bit_identical() {
    let scalar = simd::by_backend(Backend::Scalar);
    let dispatched = simd::active();
    let mut rng = XorShift128Plus::new(7);
    for bits in [2u8, 4, 8] {
        for n in DIMS {
            let (qm, p, x) = setup(11, n, bits, 2000 + n as u64 + bits as u64);
            let q8 = Quantizer::new(8);
            let (xq, xscale) = q8.quantize_auto(&x, &mut rng);
            let x_mult = xscale / q8.half() as f32;
            let want = lowprec::packed_matvec_q8_with(scalar, &p, &xq, x_mult);
            let got = lowprec::packed_matvec_q8_with(dispatched, &p, &xq, x_mult);
            // Integer accumulation → the float product is computed from the
            // same exact i64, so equality is exact.
            assert_eq!(got, want, "bits={bits} n={n}");
            // Sanity anchor: approximates the dequantized dense product.
            let xdq = q8.dequantize_slice(&xq, xscale);
            let dense = qm.to_mat().matvec(&xdq);
            close(&got, &dense, &format!("q8-vs-dense bits={bits} n={n}"));
        }
    }
}

#[test]
fn packed_scale_add_backend_matrix() {
    let scalar = simd::by_backend(Backend::Scalar);
    let dispatched = simd::active();
    for bits in [2u8, 4, 8] {
        for n in DIMS {
            let (_, p, _) = setup(9, n, bits, 3000 + n as u64 + bits as u64);
            let idx = vec![0usize, 4, 7];
            let vals = vec![0.75f32, -1.25, 0.5];
            let want = lowprec::packed_scale_add_with(scalar, &p, &idx, &vals);
            let got = lowprec::packed_scale_add_with(dispatched, &p, &idx, &vals);
            close(&got, &want, &format!("scale_add bits={bits} n={n}"));
        }
    }
}

#[test]
fn decode_row_backend_matrix_bit_identical() {
    let scalar = simd::by_backend(Backend::Scalar);
    let dispatched = simd::active();
    for bits in [2u8, 4, 8] {
        for n in DIMS {
            let (qm, p, _) = setup(3, n, bits, 4000 + n as u64 + bits as u64);
            let mut a = vec![0i8; n];
            let mut b = vec![0i8; n];
            for row in 0..3 {
                scalar.decode_row(p.row_words(row), bits, n, &mut a);
                dispatched.decode_row(p.row_words(row), bits, n, &mut b);
                assert_eq!(a, b, "bits={bits} n={n} row={row}");
                assert_eq!(&a[..], &qm.codes[row * n..(row + 1) * n], "vs codes");
            }
        }
    }
}

#[test]
fn mixed_dot_kernels_backend_matrix() {
    // The mixed int·f32 kernels (i8/u8 dots, scale-and-add) across every
    // named backend and ragged lengths — this is the row that covers the
    // NEON `vcvtq_f32_s32` + `vfmaq_f32` implementation on aarch64
    // (backends unavailable on the host resolve to the scalar reference,
    // so the matrix is runnable everywhere).
    let scalar = simd::by_backend(Backend::Scalar);
    let mut rng = XorShift128Plus::new(21);
    for n in [0usize, 1, 15, 16, 17, 64, 127, 300] {
        let irow: Vec<i8> = (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let urow: Vec<u8> = (0..n).map(|_| rng.below(129) as u8).collect();
        let x = rng.gaussian_vec(n);
        let base = rng.gaussian_vec(n);
        let want_i = scalar.dot_i8_f32(&irow, &x);
        let want_u = scalar.dot_u8_f32(&urow, &x);
        let mut want_sa = base.clone();
        scalar.scale_add_i8(&mut want_sa, &irow, -0.61);
        for b in [Backend::Avx2, Backend::Neon, Backend::Vnni, Backend::Scalar] {
            let k = simd::by_backend(b);
            let gi = k.dot_i8_f32(&irow, &x);
            assert!(
                (gi - want_i).abs() <= 1e-3 * (1.0 + want_i.abs()),
                "{b:?} dot_i8 n={n}: {gi} vs {want_i}"
            );
            let gu = k.dot_u8_f32(&urow, &x);
            assert!(
                (gu - want_u).abs() <= 1e-3 * (1.0 + want_u.abs()),
                "{b:?} dot_u8 n={n}: {gu} vs {want_u}"
            );
            let mut got_sa = base.clone();
            k.scale_add_i8(&mut got_sa, &irow, -0.61);
            for (g, w) in got_sa.iter().zip(&want_sa) {
                assert!(
                    (g - w).abs() <= 1e-4 * (1.0 + w.abs()),
                    "{b:?} scale_add n={n}"
                );
            }
        }
    }
}

#[test]
fn ragged_tail_property_matrix_every_backend_bit_identical() {
    // EVERY n mod word-capacity: n in 1..=70 sweeps all residues of the
    // 2-bit (32/word), 4-bit (16/word) and 8-bit (8/word) packings, plus
    // SIMD-group residues (16/32/64-lane groups); larger ragged sizes
    // catch the FLUSH / multi-group paths. decode_row and the integer
    // field dot must be bit-identical to scalar on every named backend,
    // single-RHS and multi-RHS alike (unavailable backends resolve to
    // scalar, so the matrix runs everywhere).
    let scalar = simd::by_backend(Backend::Scalar);
    let backends = [Backend::Avx2, Backend::Neon, Backend::Vnni];
    let mut rng = XorShift128Plus::new(99);
    let sizes: Vec<usize> = (1..=70).chain([127, 128, 129, 255, 257, 384]).collect();
    for bits in [2u8, 4, 8] {
        let q = Quantizer::new(bits);
        for &n in &sizes {
            // Random codes straight through the packer (one row).
            let codes: Vec<i8> = (0..n)
                .map(|_| (rng.below(2 * q.half() as u64 + 1) as i32 - q.half()) as i8)
                .collect();
            let qm = QuantizedMatrix { codes: codes.clone(), m: 1, n, bits, scale: 1.0 };
            let p = PackedMatrix::pack(&qm);
            let words = p.row_words(0);
            let xqs: Vec<Vec<i8>> = (0..3)
                .map(|_| (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect())
                .collect();
            let xq_refs: Vec<&[i8]> = xqs.iter().map(|v| v.as_slice()).collect();

            let mut want_dec = vec![0i8; n];
            scalar.decode_row(words, bits, n, &mut want_dec);
            assert_eq!(want_dec, codes, "scalar decode vs source codes bits={bits} n={n}");
            let want_dots: Vec<i64> = xq_refs
                .iter()
                .map(|xq| scalar.packed_field_dot_q8(words, bits, n, xq))
                .collect();
            let mut want_multi = vec![0i64; 3];
            scalar.packed_field_dot_q8_multi(words, bits, n, &xq_refs, &mut want_multi);
            assert_eq!(want_multi, want_dots, "scalar multi vs single bits={bits} n={n}");

            for b in backends {
                let k = simd::by_backend(b);
                let mut got_dec = vec![0i8; n];
                k.decode_row(words, bits, n, &mut got_dec);
                assert_eq!(got_dec, want_dec, "{b:?} decode bits={bits} n={n}");
                for (xq, want) in xq_refs.iter().zip(&want_dots) {
                    let got = k.packed_field_dot_q8(words, bits, n, xq);
                    assert_eq!(got, *want, "{b:?} field_dot bits={bits} n={n}");
                }
                let mut got_multi = vec![0i64; 3];
                k.packed_field_dot_q8_multi(words, bits, n, &xq_refs, &mut got_multi);
                assert_eq!(got_multi, want_dots, "{b:?} multi field_dot bits={bits} n={n}");
            }
        }
    }
}

#[test]
fn multi_rhs_matvec_matches_single_across_backends() {
    // packed_matvec_multi must be bit-identical per RHS to repeated
    // single-RHS calls on the same backend — the contract that lets the
    // batched solver substitute the amortized sweep for per-job matvecs.
    let scalar = simd::by_backend(Backend::Scalar);
    let dispatched = simd::active();
    let mut rng = XorShift128Plus::new(31);
    for bits in [2u8, 4, 8] {
        for n in [17usize, 64, 65, 127, 300] {
            let (_, p, _) = setup(19, n, bits, 6000 + n as u64 + bits as u64);
            let xs: Vec<Vec<f32>> = (0..5).map(|_| rng.gaussian_vec(n)).collect();
            let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
            for k in [scalar, dispatched] {
                let got = lowprec::packed_matvec_multi_with(k, &p, &refs);
                for (j, x) in xs.iter().enumerate() {
                    let want = lowprec::packed_matvec_with(k, &p, x);
                    assert_eq!(
                        got[j],
                        want,
                        "{} bits={bits} n={n} rhs={j}",
                        k.name()
                    );
                }
            }
        }
    }
}

#[test]
fn multi_rhs_matvec_thread_count_invariant() {
    // Same sweep, pool pinned to one thread: bit-identical outputs. Uses
    // par::set_thread_override, not env mutation (getenv race is UB).
    let (_, p, _) = setup(37, 300, 4, 7000);
    let mut rng = XorShift128Plus::new(41);
    let xs: Vec<Vec<f32>> = (0..3).map(|_| rng.gaussian_vec(300)).collect();
    let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
    let par = lowprec::packed_matvec_multi(&p, &refs);
    lpcs::par::set_thread_override(Some(1));
    let one = lowprec::packed_matvec_multi(&p, &refs);
    lpcs::par::set_thread_override(None);
    assert_eq!(par, one, "multi-RHS matvec must not depend on thread count");
}

#[test]
fn pool_single_thread_matches_parallel_exactly() {
    // Compute with default parallelism first, then pin the pool to one
    // thread and recompute: outputs must be bit-identical (same backend,
    // same per-element accumulation order and 8-aligned FMA grid regardless
    // of chunking). Uses par::set_thread_override — not env mutation, which
    // would race concurrent getenv calls from sibling tests (UB on glibc).
    let (qm, p, x) = setup(37, 300, 4, 5000);
    let qt = qm.transposed();
    let pt = PackedMatrix::pack(&qt);
    let idx = vec![2usize, 9, 33];
    let vals = vec![1.0f32, -0.5, 0.25];
    let v: Vec<f32> = x[..37.min(x.len())].to_vec();

    let mv_par = lowprec::packed_matvec(&p, &x);
    let sa_par = lowprec::packed_scale_add(&pt, &idx, &vals);
    let sp_par = lowprec::qmatvec_sparse(&qt.codes, qm.n, qm.m, qm.multiplier(), &idx, &vals);
    let q_par = lowprec::qmatvec(&qm.codes, qm.m, qm.n, qm.multiplier(), &x);
    let t_par = lowprec::qmatvec_t(&qm.codes, qm.m, qm.n, qm.multiplier(), &v);

    lpcs::par::set_thread_override(Some(1));
    let mv_one = lowprec::packed_matvec(&p, &x);
    let sa_one = lowprec::packed_scale_add(&pt, &idx, &vals);
    let sp_one = lowprec::qmatvec_sparse(&qt.codes, qm.n, qm.m, qm.multiplier(), &idx, &vals);
    let q_one = lowprec::qmatvec(&qm.codes, qm.m, qm.n, qm.multiplier(), &x);
    let t_one = lowprec::qmatvec_t(&qm.codes, qm.m, qm.n, qm.multiplier(), &v);
    lpcs::par::set_thread_override(None);

    assert_eq!(mv_par, mv_one, "packed_matvec");
    assert_eq!(sa_par, sa_one, "packed_scale_add");
    assert_eq!(sp_par, sp_one, "qmatvec_sparse");
    assert_eq!(q_par, q_one, "qmatvec");
    assert_eq!(t_par, t_one, "qmatvec_t");
}
