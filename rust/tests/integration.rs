//! Cross-module integration: telescope → solvers → metrics → service, and
//! the Theorem-3 error bound checked end-to-end against measured errors.

use lpcs::algorithms::cosamp::cosamp;
use lpcs::algorithms::fista::{fista, FistaOptions};
use lpcs::algorithms::niht::niht_dense;
use lpcs::algorithms::qniht::{qniht, RequantMode};
use lpcs::algorithms::SolveOptions;
use lpcs::config::{EngineKind, ServiceConfig};
use lpcs::coordinator::{JobSpec, ProblemHandle, RecoveryService};
use lpcs::linalg::{self, Mat};
use lpcs::metrics;
use lpcs::rip;
use lpcs::rng::XorShift128Plus;
use lpcs::telescope::{AstroConfig, AstroProblem};
use std::sync::Arc;
use std::time::Duration;

fn small_astro(seed: u64) -> AstroProblem {
    AstroProblem::build(
        &AstroConfig {
            antennas: 10,
            resolution: 24,
            sources: 8,
            snr_db: 20.0,
            ..Default::default()
        },
        seed,
    )
}

#[test]
fn astro_pipeline_niht_recovers_sources() {
    let p = small_astro(1);
    let r = niht_dense(&p.phi, &p.y, 8, &SolveOptions::default());
    let resolved = metrics::sources_resolved(&r.x, &p.sky.sources, 24, 1, 0.5);
    assert!(resolved >= 7, "resolved {resolved}/8");
}

#[test]
fn astro_pipeline_low_precision_matches_dense_on_sources() {
    let p = small_astro(2);
    let d = niht_dense(&p.phi, &p.y, 8, &SolveOptions::default());
    let q = qniht(&p.phi, &p.y, 8, 2, 8, RequantMode::Fixed, 7, &SolveOptions::default());
    let res_d = metrics::sources_resolved(&d.x, &p.sky.sources, 24, 1, 0.4);
    let res_q = metrics::sources_resolved(&q.x, &p.sky.sources, 24, 1, 0.4);
    // The paper's headline: 2-bit loses almost nothing on sky recovery.
    assert!(res_q + 2 >= res_d, "2-bit resolved {res_q} vs dense {res_d}");
}

#[test]
fn all_solvers_agree_on_well_posed_gaussian() {
    let (m, n, s) = (96usize, 192usize, 5usize);
    let mut rng = XorShift128Plus::new(3);
    let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
    let mut x = vec![0.0f32; n];
    for i in rng.choose_k(n, s) {
        x[i] = 2.0 * rng.gaussian_f32().signum();
    }
    let y = phi.matvec(&x);
    let opts = SolveOptions { max_iters: 300, ..Default::default() };
    let solutions = [
        niht_dense(&phi, &y, s, &opts).x,
        cosamp(&phi, &y, s, &opts).x,
        fista(&phi, &y, &opts, &FistaOptions { prune_to: Some(s), ..Default::default() }).x,
        qniht(&phi, &y, s, 8, 8, RequantMode::Fixed, 1, &opts).x,
    ];
    for (k, sol) in solutions.iter().enumerate() {
        let err = metrics::recovery_error(sol, &x);
        assert!(err < 0.05, "solver {k} err={err}");
    }
}

#[test]
fn theorem3_bound_holds_empirically() {
    // ε_q from Theorem 3 must upper-bound the measured EXTRA error of the
    // quantized solve vs the dense solve on a noiseless exactly-sparse
    // problem (where ε_s = 0).
    let p = small_astro(4);
    let s = 8;
    let d = niht_dense(&p.phi, &p.y, s, &SolveOptions::default());
    let est = rip::ric_probe(&p.phi, 2 * s, 4, 11);
    for bits in [2u8, 4, 8] {
        let q = qniht(&p.phi, &p.y, s, bits, 8, RequantMode::Fresh, 13, &SolveOptions::default());
        let extra = (linalg::norm2(&linalg::sub(&q.x, &p.x_true)) as f64
            - linalg::norm2(&linalg::sub(&d.x, &p.x_true)) as f64)
            .max(0.0);
        let xs_norm = linalg::norm2(&p.x_true) as f64;
        let eq = rip::epsilon_q(p.m(), est.beta as f64, xs_norm, bits as u32, 8);
        assert!(
            extra <= 5.0 * eq + 0.05 * xs_norm,
            "bits={bits}: extra error {extra} exceeds theorem bound 5ε_q={}",
            5.0 * eq
        );
    }
}

#[test]
fn service_runs_astro_jobs_end_to_end() {
    let p = small_astro(5);
    let phi = Arc::new(p.phi.clone());
    let service = RecoveryService::start(
        ServiceConfig {
            workers: 2,
            queue_capacity: 16,
            max_batch: 4,
            max_wait_ms: 0,
            ..Default::default()
        },
        SolveOptions::default(),
        std::path::PathBuf::from("artifacts"),
    );
    let mut ids = vec![];
    for k in 0..6u64 {
        ids.push(
            service
                .submit(
                    JobSpec::builder(ProblemHandle::new(phi.clone()), p.y.clone(), 8)
                        .bits(4, 8)
                        .engine(EngineKind::NativeQuant)
                        .seed(k)
                        .build(),
                )
                .unwrap(),
        );
    }
    for id in ids {
        let out = service.wait(id, Duration::from_secs(120)).expect("finishes");
        let res = out.result.expect("has result");
        let resolved = metrics::sources_resolved(&res.x, &p.sky.sources, 24, 1, 0.4);
        assert!(resolved >= 6, "resolved {resolved}/8");
    }
    service.shutdown();
}

#[test]
fn fpga_model_end_to_end_speedup_shape() {
    // Combining real iteration counts with the bandwidth model must give a
    // super-4x end-to-end win for 2&8-bit whenever the iteration overhead
    // is < 4x — the Fig 6 crossover structure.
    let p = small_astro(6);
    let s = 8;
    let fpga = lpcs::perfmodel::fpga::FpgaModel::default();
    let opts_k = |k: usize| SolveOptions { max_iters: k, tol: 0.0, ..Default::default() };
    // Metric: sources resolved within 1 pixel (the paper's tolerance
    // metric); 0.85 = 7/8 sources at s = 8 granularity.
    let it32 = lpcs::repro::iterations_to_sources_resolved(
        |k| niht_dense(&p.phi, &p.y, s, &opts_k(k)).x,
        &p.sky.sources,
        24,
        0.85,
        256,
    )
    .expect("dense reaches 85%");
    // Fresh quantizations per iteration: the FPGA recomputes Φ on the fly
    // (paper §8.2), so per-iteration stochastic rounding is the faithful
    // model of that deployment, and it reliably reaches 90% support.
    let it2 = lpcs::repro::iterations_to_sources_resolved(
        |k| qniht(&p.phi, &p.y, s, 2, 8, RequantMode::Fresh, 3, &opts_k(k)).x,
        &p.sky.sources,
        24,
        0.85,
        256,
    )
    .expect("2-bit reaches 85%");
    let t32 = fpga.end_to_end_time(p.m(), p.n(), 32, 32, it32);
    let t2 = fpga.end_to_end_time(p.m(), p.n(), 2, 8, it2);
    let speedup = t32 / t2;
    assert!(speedup > 2.0, "end-to-end speedup {speedup} (it32={it32}, it2={it2})");
}
