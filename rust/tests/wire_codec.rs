//! Wire-codec conformance: every frame the protocol can carry must
//! round-trip bit-identically through encode/decode over
//! `testkit::forall`-generated payloads (including empty and max-size
//! vectors), and the decoder must reject truncated frames, corrupted
//! checksums, and unknown version bytes with typed errors — never a
//! panic (a network peer controls these bytes).

use lpcs::algorithms::qniht::RequantMode;
use lpcs::algorithms::IterStat;
use lpcs::config::EngineKind;
use lpcs::coordinator::JobState;
use lpcs::mri::MaskKind;
use lpcs::rng::XorShift128Plus;
use lpcs::solver::SolverKind;
use lpcs::testkit;
use lpcs::wire::{
    checksum, decode, encode, route_key, BackendStats, DecodeError, ErrCode, Message,
    WireJobSpec, WireOutcome, WireProblem, WireResult, MIN_WIRE_VERSION, WIRE_VERSION,
};

fn rand_stat(rng: &mut XorShift128Plus) -> IterStat {
    IterStat {
        iter: rng.below(100_000),
        resid_nsq: rng.gaussian_f32().abs(),
        mu: rng.gaussian_f32(),
        support_changed: rng.below(2) == 1,
        shrink_count: rng.below(50),
    }
}

fn rand_problem(rng: &mut XorShift128Plus) -> WireProblem {
    if rng.below(2) == 0 {
        // Dense, including degenerate 0×0 (empty data vector).
        let (rows, cols) = if rng.below(8) == 0 {
            (0, 0)
        } else {
            (1 + rng.below(8), 1 + rng.below(16))
        };
        WireProblem::Dense {
            rows,
            cols,
            data: rng.gaussian_vec(rows * cols),
            shape_tag: if rng.below(2) == 0 {
                Some(format!("tag_{}", rng.below(1000)))
            } else {
                None
            },
        }
    } else {
        let r = 1 << (2 + rng.below(3)); // 4..16
        let n_pts = 1 + rng.below(r * r - 1);
        // Strictly ascending in-range points.
        let mut points: Vec<usize> = rng.choose_k(r * r, n_pts);
        points.sort_unstable();
        WireProblem::PartialFourier {
            r,
            kind: if rng.below(2) == 0 { MaskKind::Cartesian } else { MaskKind::Radial },
            fraction: rng.uniform_f32(),
            center_band: 1 + rng.below(4),
            points,
            bits: match rng.below(4) {
                0 => None,
                1 => Some(2),
                2 => Some(4),
                _ => Some(8),
            },
        }
    }
}

fn rand_solver(rng: &mut XorShift128Plus) -> SolverKind {
    match rng.below(5) {
        0 => SolverKind::Niht,
        1 => SolverKind::Iht,
        2 => SolverKind::Qniht {
            bits_phi: [2u8, 4, 8][rng.below(3)],
            bits_y: [2u8, 4, 8][rng.below(3)],
            mode: if rng.below(2) == 0 { RequantMode::Fixed } else { RequantMode::Fresh },
        },
        3 => SolverKind::Cosamp,
        _ => SolverKind::Fista {
            lambda: if rng.below(2) == 0 { Some(rng.gaussian_f32().abs()) } else { None },
            debias: rng.below(2) == 1,
        },
    }
}

fn rand_outcome(rng: &mut XorShift128Plus) -> WireOutcome {
    WireOutcome {
        id: rng.next_u64(),
        state: [JobState::Queued, JobState::Running, JobState::Done, JobState::Failed]
            [rng.below(4)],
        result: if rng.below(2) == 0 {
            Some(WireResult {
                x: rng.gaussian_vec(rng.below(64)), // includes empty
                iterations: rng.below(100_000) as u64,
                converged: rng.below(2) == 1,
                shrink_events: rng.below(100) as u64,
                history: (0..rng.below(20)).map(|_| rand_stat(rng)).collect(),
            })
        } else {
            None
        },
        error: if rng.below(2) == 0 { Some(format!("err {}", rng.below(100))) } else { None },
        queued_us: rng.next_u64() >> 20,
        ran_us: rng.next_u64() >> 20,
        trace: if rng.below(2) == 0 { rng.next_u64() } else { 0 },
    }
}

fn rand_message(rng: &mut XorShift128Plus) -> Message {
    match rng.below(13) {
        0 => Message::Submit(WireJobSpec {
            problem: rand_problem(rng),
            y: rng.gaussian_vec(rng.below(32)), // includes empty
            s: 1 + rng.below(16),
            solver: rand_solver(rng),
            engine: [
                EngineKind::NativeDense,
                EngineKind::NativeQuant,
                EngineKind::XlaQuant,
                EngineKind::XlaDense,
                EngineKind::FpgaModel,
            ][rng.below(5)],
            seed: rng.next_u64(),
            trace: if rng.below(2) == 0 { rng.next_u64() } else { 0 },
        }),
        1 => Message::Submitted {
            id: rng.next_u64(),
            trace: if rng.below(2) == 0 { rng.next_u64() } else { 0 },
        },
        2 => Message::Subscribe { id: rng.next_u64() },
        3 => Message::Cancel { id: rng.next_u64() },
        4 => Message::Cancelled { id: rng.next_u64(), accepted: rng.below(2) == 1 },
        5 => Message::Progress {
            id: rng.next_u64(),
            epoch: rng.below(8) as u32, // router resume epochs
            stat: rand_stat(rng),
            trace: if rng.below(2) == 0 { rng.next_u64() } else { 0 },
        },
        6 => Message::Done(rand_outcome(rng)),
        7 => Message::MetricsReq,
        8 => Message::Metrics {
            snapshot: if rng.below(4) == 0 {
                String::new()
            } else {
                format!("submitted={} completed={}", rng.below(100), rng.below(100))
            },
        },
        9 => Message::Err {
            code: ErrCode::ALL[rng.below(ErrCode::ALL.len())],
            msg: if rng.below(4) == 0 { String::new() } else { "queue full".into() },
            retry_after_ms: if rng.below(2) == 0 {
                Some(rng.next_u64() >> 40)
            } else {
                None
            },
        },
        10 => Message::QueuePos {
            id: rng.next_u64(),
            position: rng.below(1000) as u64,
            depth: rng.below(1000) as u64,
        },
        11 => Message::StatsReq,
        _ => Message::Stats(BackendStats {
            queue_depth: rng.below(1000) as u64,
            queue_capacity: rng.below(1000) as u64,
            workers: rng.below(64) as u64,
        }),
    }
}

#[test]
fn every_frame_kind_round_trips_over_generated_payloads() {
    testkit::forall("wire-frame-roundtrip", 0xC0DEC, 300, |rng, _| {
        let msg = rand_message(rng);
        let frame = encode(&msg);
        let (back, used) = decode(&frame).unwrap_or_else(|e| panic!("{msg:?}: {e}"));
        assert_eq!(used, frame.len(), "whole frame consumed");
        assert_eq!(back, msg, "decode(encode(m)) == m");
    });
}

#[test]
fn max_size_and_empty_payloads_round_trip() {
    // A deliberately fat frame: 64×128 dense Φ + a long history.
    let mut rng = XorShift128Plus::new(99);
    let fat = Message::Submit(WireJobSpec {
        problem: WireProblem::Dense {
            rows: 64,
            cols: 128,
            data: rng.gaussian_vec(64 * 128),
            shape_tag: Some("fat".into()),
        },
        y: rng.gaussian_vec(64),
        s: 8,
        solver: SolverKind::qniht_fixed(2, 8),
        engine: EngineKind::NativeQuant,
        seed: 7,
        trace: u64::MAX,
    });
    let done = Message::Done(WireOutcome {
        id: 1,
        state: JobState::Done,
        result: Some(WireResult {
            x: rng.gaussian_vec(4096),
            iterations: 1000,
            converged: true,
            shrink_events: 3,
            history: (0..1000).map(|_| rand_stat(&mut rng)).collect(),
        }),
        error: None,
        queued_us: 5,
        ran_us: 9,
        trace: 0x1122_3344_5566_7788,
    });
    // And the empty extremes.
    let empty_y = Message::Submit(WireJobSpec {
        problem: WireProblem::Dense { rows: 0, cols: 0, data: vec![], shape_tag: None },
        y: vec![],
        s: 1,
        solver: SolverKind::Niht,
        engine: EngineKind::NativeDense,
        seed: 0,
        trace: 0,
    });
    let empty_result = Message::Done(WireOutcome {
        id: 0,
        state: JobState::Failed,
        result: Some(WireResult {
            x: vec![],
            iterations: 0,
            converged: false,
            shrink_events: 0,
            history: vec![],
        }),
        error: Some(String::new()),
        queued_us: 0,
        ran_us: 0,
        trace: 0,
    });
    for msg in [fat, done, empty_y, empty_result] {
        let frame = encode(&msg);
        let (back, used) = decode(&frame).unwrap();
        assert_eq!(used, frame.len());
        assert_eq!(back, msg);
    }
}

#[test]
fn truncated_frames_are_rejected_at_every_cut_without_panicking() {
    let mut rng = XorShift128Plus::new(0xBAD);
    for _ in 0..20 {
        let frame = encode(&rand_message(&mut rng));
        // Exhaustive for small frames, sampled for big ones.
        let cuts: Vec<usize> = if frame.len() <= 256 {
            (0..frame.len()).collect()
        } else {
            (0..256).map(|_| rng.below(frame.len())).collect()
        };
        for cut in cuts {
            assert_eq!(
                decode(&frame[..cut]),
                Err(DecodeError::Truncated),
                "cut at {cut}/{}",
                frame.len()
            );
        }
    }
}

#[test]
fn corrupted_frames_are_rejected_with_typed_errors() {
    let mut rng = XorShift128Plus::new(0xC0FFEE);
    for case in 0..50 {
        let frame = encode(&rand_message(&mut rng));
        // Unknown version byte. The decoder accepts the whole tolerant
        // window MIN_WIRE_VERSION..=WIRE_VERSION, so step the perturbed
        // byte past any accepted version it lands on (a still-accepted
        // version fails later, at the checksum, not as BadVersion).
        let mut bad = frame.clone();
        let mut v = bad[0].wrapping_add(1 + rng.below(254) as u8);
        while (MIN_WIRE_VERSION..=WIRE_VERSION).contains(&v) {
            v = v.wrapping_add(1);
        }
        bad[0] = v;
        assert!(
            matches!(decode(&bad), Err(DecodeError::BadVersion(_))),
            "case {case}: version"
        );
        // A flipped bit anywhere in tag/length/payload/checksum fails the
        // checksum (or the length/version guards) — never panics, never
        // yields a wrong message silently.
        let mut bad = frame.clone();
        let pos = 1 + rng.below(bad.len() - 1);
        bad[pos] ^= 1 << rng.below(8);
        match decode(&bad) {
            Err(_) => {}
            Ok((msg, _)) => panic!("case {case}: corrupted frame decoded as {msg:?}"),
        }
    }
}

#[test]
fn unknown_tag_rejected_even_with_valid_checksum() {
    let frame = encode(&Message::MetricsReq);
    let mut bad = frame;
    bad[1] = 0xEE;
    let body_end = bad.len() - 4;
    let sum = checksum(&bad[..body_end]);
    bad[body_end..].copy_from_slice(&sum.to_le_bytes());
    assert_eq!(decode(&bad), Err(DecodeError::UnknownTag(0xEE)));
}

#[test]
fn garbage_buffers_never_panic_the_decoder() {
    testkit::forall("wire-garbage", 0xDEAD, 200, |rng, _| {
        let n = rng.below(64);
        let garbage: Vec<u8> = (0..n).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        let _ = decode(&garbage); // any Err is fine; a panic is not
        // And garbage wearing a valid header prefix (tag range covers
        // every real tag plus unknown ones).
        let mut framed = vec![WIRE_VERSION, (rng.below(16)) as u8];
        framed.extend_from_slice(&(n as u32).to_le_bytes());
        framed.extend_from_slice(&garbage);
        framed.extend_from_slice(&checksum(&framed).to_le_bytes());
        let _ = decode(&framed);
    });
}

#[test]
fn wire_spec_reconstructs_the_in_process_spec() {
    use lpcs::coordinator::{JobSpec, OperatorSpec, ProblemHandle};
    use lpcs::mri::{MaskConfig, PartialFourierOp, SamplingMask};
    use lpcs::Mat;
    use std::sync::Arc;

    // Dense: operator content, tag, and every scalar survive the trip.
    let mut rng = XorShift128Plus::new(11);
    let phi = Arc::new(Mat::from_fn(6, 10, |_, _| rng.gaussian_f32()));
    let spec = JobSpec::builder(
        ProblemHandle::with_shape_tag(phi.clone(), "roundtrip"),
        rng.gaussian_vec(6),
        3,
    )
    .bits(4, 8)
    .seed(21)
    .build();
    let back = WireJobSpec::from_spec(&spec).into_spec().unwrap();
    assert_eq!(back.problem.as_dense().unwrap().data, phi.data);
    assert_eq!(back.problem.shape_tag.as_deref(), Some("roundtrip"));
    assert_eq!(back.y, spec.y);
    assert_eq!((back.s, back.solver, back.engine, back.seed), (3, spec.solver, spec.engine, 21));
    back.validate().unwrap();

    // Matrix-free: the reconstructed mask is the client's mask, point
    // for point, and the low-precision bit width rides along.
    let mask = SamplingMask::generate(&MaskConfig::default(), 16, 3).unwrap();
    let op = Arc::new(PartialFourierOp::new(mask));
    let m = ProblemHandle::partial_fourier(op.clone()).m();
    let spec = JobSpec::builder(ProblemHandle::low_prec_fourier(op.clone(), 8), vec![0.5; m], 4)
        .engine(EngineKind::NativeDense)
        .solver(SolverKind::Niht)
        .build();
    let back = WireJobSpec::from_spec(&spec).into_spec().unwrap();
    match &back.problem.op {
        OperatorSpec::PartialFourier { op: rebuilt, bits } => {
            assert_eq!(rebuilt.mask().points(), op.mask().points());
            assert_eq!(rebuilt.mask().r(), 16);
            assert_eq!(*bits, Some(8));
        }
        other => panic!("wrong operator: {other:?}"),
    }
    back.validate().unwrap();

    // A lying dense payload (data ≠ rows×cols) is caught at reconstruction.
    let lying = WireJobSpec {
        problem: WireProblem::Dense { rows: 4, cols: 4, data: vec![0.0; 3], shape_tag: None },
        y: vec![0.0; 4],
        s: 1,
        solver: SolverKind::Niht,
        engine: EngineKind::NativeDense,
        seed: 0,
    };
    assert!(lying.into_spec().unwrap_err().to_string().contains("4x4"));
}

#[test]
fn route_key_tracks_batch_identity_not_payload() {
    // The router's placement key must be blind to everything that does
    // NOT affect batchability (y, seed) and sensitive to everything
    // that does (operator content, s, solver, engine) — that is what
    // makes same-BatchKey jobs land on one backend and keep batching.
    testkit::forall("route-key-batch-identity", 0x40F7E, 100, |rng, _| {
        let base = WireJobSpec {
            problem: rand_problem(rng),
            y: rng.gaussian_vec(rng.below(32)),
            s: 1 + rng.below(16),
            solver: rand_solver(rng),
            engine: EngineKind::NativeDense,
            seed: rng.next_u64(),
        };
        let key = route_key(&base);
        assert_eq!(key, route_key(&base), "deterministic");

        let mut other_payload = base.clone();
        other_payload.y = rng.gaussian_vec(other_payload.y.len() + 1);
        other_payload.seed = base.seed.wrapping_add(1);
        assert_eq!(key, route_key(&other_payload), "y and seed are not batch identity");

        let mut other_s = base.clone();
        other_s.s += 1;
        assert_ne!(key, route_key(&other_s), "sparsity is batch identity");

        let mut other_engine = base.clone();
        other_engine.engine = EngineKind::NativeQuant;
        assert_ne!(key, route_key(&other_engine), "engine is batch identity");

        let mut other_solver = base.clone();
        other_solver.solver = match base.solver {
            SolverKind::Niht => SolverKind::Cosamp,
            _ => SolverKind::Niht,
        };
        assert_ne!(key, route_key(&other_solver), "solver is batch identity");
    });
}
