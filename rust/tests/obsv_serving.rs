//! Observability conformance over the wire, on real serving stacks
//! (`testkit::ServiceHarness` / `RouterHarness`):
//!
//! * a `ScrapeReq` frame against a live server returns a Prometheus
//!   text exposition that is **internally consistent even mid-load**:
//!   for every label set, `lpcs_job_e2e_us_count` equals the
//!   outcome-labeled `lpcs_jobs_total` counter (both must come from one
//!   snapshot of the same histogram family — the structural invariant
//!   the `obsv` layer guarantees), every cumulative `_bucket` series is
//!   monotone in `le`, and the `+Inf` bucket equals `_count`;
//! * after the load drains, the `ok` totals account for every job per
//!   (solver, engine, bits) label set and the in-flight gauge is back
//!   to zero;
//! * the router face answers `ScrapeReq` with the **federated** fleet
//!   exposition: its own routing counters and per-hop histograms
//!   (labeled by backend) plus every backend's families merged — with
//!   the same internal-consistency invariants holding on the merge, a
//!   trace-id exemplar surviving the round trip, and a killed backend
//!   degrading to a `lpcs_backend_scrape_errors` increment instead of a
//!   stalled or inconsistent scrape.

use lpcs::algorithms::SolveOptions;
use lpcs::config::{EngineKind, ServiceConfig};
use lpcs::coordinator::{JobSpec, ProblemHandle};
use lpcs::rng::XorShift128Plus;
use lpcs::solver::SolverKind;
use lpcs::testkit::{RouterHarness, ServiceHarness};
use lpcs::wire::WatchEvent;
use lpcs::Mat;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

fn planted(m: usize, n: usize, s: usize, seed: u64) -> (Arc<Mat>, Vec<f32>) {
    let mut rng = XorShift128Plus::new(seed);
    let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
    let mut x = vec![0.0f32; n];
    for i in rng.choose_k(n, s) {
        x[i] = 2.0 * rng.gaussian_f32().signum() + 0.3 * rng.gaussian_f32();
    }
    let y = phi.matvec(&x);
    (Arc::new(phi), y)
}

/// Parse an exposition into `series{labels} -> value`, ignoring
/// `# HELP`/`# TYPE` lines and OpenMetrics exemplar suffixes
/// (`… # {trace_id="…"} v`). Values in our expositions are integral.
fn parse(text: &str) -> HashMap<String, u64> {
    let mut out = HashMap::new();
    for line in text.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let line = line.split(" # ").next().unwrap();
        let (series, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("bad line: {line}"));
        let value: u64 = value.parse().unwrap_or_else(|_| panic!("non-integer value: {line}"));
        assert!(out.insert(series.to_string(), value).is_none(), "duplicate series: {series}");
    }
    out
}

/// The `le` bound of a `_bucket` series key, as a sortable float.
fn le_bound(series: &str) -> f64 {
    let le = series.split("le=\"").nth(1).expect("bucket has le").trim_end_matches("\"}");
    if le == "+Inf" {
        f64::INFINITY
    } else {
        le.parse().unwrap_or_else(|_| panic!("bad le bound in {series}"))
    }
}

/// The structural invariants a scrape must satisfy *at any instant*,
/// including with jobs queued and running while we parse.
fn assert_internally_consistent(parsed: &HashMap<String, u64>) {
    // Cumulative buckets are monotone in `le` and end at `_count`.
    let mut families: HashMap<(String, String), Vec<(f64, u64)>> = HashMap::new();
    for (k, v) in parsed {
        let Some(idx) = k.find("_bucket{") else { continue };
        let name = k[..idx].to_string();
        let labels = k[idx + 7..].split(",le=").next().expect("labels before le").to_string();
        families.entry((name, labels)).or_default().push((le_bound(k), *v));
    }
    assert!(!families.is_empty(), "no _bucket series in the exposition");
    for ((name, labels), mut buckets) in families {
        buckets.sort_by(|a, b| a.0.total_cmp(&b.0));
        assert!(
            buckets.windows(2).all(|w| w[0].1 <= w[1].1),
            "{name}{labels}: cumulative buckets not monotone: {buckets:?}"
        );
        let (inf, inf_v) = *buckets.last().unwrap();
        assert!(inf.is_infinite(), "{name}{labels}: missing +Inf bucket");
        let count = parsed[&format!("{name}_count{labels}}}")];
        assert_eq!(inf_v, count, "{name}{labels}: +Inf bucket != _count");
    }
    // The e2e histogram count and the outcome counter are two renderings
    // of the SAME family snapshot: they must agree series-for-series.
    let mut checked = 0;
    for (k, v) in parsed {
        if let Some(labels) = k.strip_prefix("lpcs_job_e2e_us_count") {
            let total = parsed
                .get(&format!("lpcs_jobs_total{labels}"))
                .unwrap_or_else(|| panic!("no lpcs_jobs_total for {k}"));
            assert_eq!(v, total, "e2e count and outcome counter disagree for {labels}");
            checked += 1;
        }
    }
    for (k, _) in parsed {
        if let Some(labels) = k.strip_prefix("lpcs_jobs_total") {
            assert!(
                parsed.contains_key(&format!("lpcs_job_e2e_us_count{labels}")),
                "outcome counter {k} has no e2e histogram"
            );
        }
    }
    assert!(checked > 0, "no terminal label sets to check yet");
}

#[test]
fn mid_load_scrape_is_internally_consistent_and_drains_to_exact_totals() {
    // One worker, batch size 1: jobs queue behind a slow one, so the
    // mid-load scrape observes a mix of terminal, running, and queued
    // jobs under two distinct label sets.
    let h = ServiceHarness::start(
        ServiceConfig {
            workers: 1,
            queue_capacity: 8,
            max_batch: 1,
            max_wait_ms: 0,
            ..Default::default()
        },
        SolveOptions::default().with_tol(0.0).with_max_iters(600),
    );
    let mut client = h.client();

    // Two short qniht jobs, fully drained first: guaranteed terminal
    // series for the quantized label set before the load phase.
    let (phi_q, y_q) = planted(96, 192, 5, 11);
    let qspec = JobSpec::builder(ProblemHandle::new(phi_q), y_q, 5)
        .solver(SolverKind::qniht_fixed(8, 8))
        .engine(EngineKind::NativeQuant)
        .seed(1)
        .build();
    for _ in 0..2 {
        let id = client.submit(&qspec).unwrap();
        h.service().wait(id, Duration::from_secs(60)).expect("qniht job drains");
    }

    // A slow dense blocker plus three dense jobs queued behind it.
    let (phi_b, y_b) = planted(256, 2048, 4, 12);
    let blocker = JobSpec::builder(ProblemHandle::new(phi_b), y_b, 4)
        .solver(SolverKind::Niht)
        .engine(EngineKind::NativeDense)
        .seed(2)
        .build();
    let (phi_d, y_d) = planted(96, 192, 5, 13);
    let dense = JobSpec::builder(ProblemHandle::new(phi_d), y_d, 5)
        .solver(SolverKind::Niht)
        .engine(EngineKind::NativeDense)
        .seed(3)
        .build();
    let mut ids = vec![client.submit(&blocker).unwrap()];
    for _ in 0..3 {
        ids.push(client.submit(&dense).unwrap());
    }

    // Mid-load: scrape over the wire while the blocker runs.
    let mid = client.scrape().expect("scrape mid-load");
    let parsed = parse(&mid);
    assert_internally_consistent(&parsed);
    assert_eq!(parsed["lpcs_workers_total"], 1);
    assert_eq!(parsed["lpcs_queue_capacity"], 8);
    assert_eq!(
        parsed["lpcs_jobs_total{solver=\"qniht\",engine=\"native-quant\",bits=\"8\",outcome=\"ok\"}"],
        2,
        "the drained qniht jobs are terminal before the load phase"
    );
    assert!(parsed["lpcs_inflight_jobs"] <= 4, "at most the four dense jobs are in flight");

    // Drain and re-scrape: exact totals per label set, gauge at zero.
    for id in ids {
        h.service().wait(id, Duration::from_secs(120)).expect("dense job drains");
    }
    let parsed = parse(&client.scrape().expect("scrape after drain"));
    assert_internally_consistent(&parsed);
    assert_eq!(
        parsed["lpcs_jobs_total{solver=\"qniht\",engine=\"native-quant\",bits=\"8\",outcome=\"ok\"}"],
        2
    );
    assert_eq!(
        parsed["lpcs_jobs_total{solver=\"niht\",engine=\"native-dense\",bits=\"32\",outcome=\"ok\"}"],
        4
    );
    assert_eq!(parsed["lpcs_inflight_jobs"], 0);
    assert_eq!(parsed["lpcs_jobs_submitted_total"], 6);
    // All four timing histograms exist for the dense label set.
    for family in ["queue_wait", "setup", "exec"] {
        assert!(
            parsed.contains_key(&format!(
                "lpcs_job_{family}_us_count{{solver=\"niht\",engine=\"native-dense\",bits=\"32\"}}"
            )),
            "missing lpcs_job_{family}_us for the dense label set"
        );
    }
    h.shutdown();
}

#[test]
fn router_scrape_federates_backend_families_with_hop_series_and_exemplars() {
    let h = RouterHarness::start(
        2,
        ServiceConfig { workers: 1, queue_capacity: 8, max_batch: 2, ..Default::default() },
        SolveOptions::default(),
    );
    let mut client = h.client();
    let (phi, y) = planted(96, 192, 5, 21);
    let spec = JobSpec::builder(ProblemHandle::new(phi), y, 5)
        .solver(SolverKind::Niht)
        .engine(EngineKind::NativeDense)
        .seed(4)
        .build();
    let id = client.submit(&spec).unwrap();
    let mut trace = 0u64;
    for event in client.watch(id).unwrap() {
        if let WatchEvent::Done(out) = event.unwrap() {
            assert!(out.error.is_none(), "{:?}", out.error);
            trace = out.trace;
        }
    }
    assert_ne!(trace, 0, "a routed job must carry a minted trace id to its watcher");

    let text = client.scrape().expect("scrape through the router");
    let parsed = parse(&text);
    // Router-own counters still lead the exposition.
    assert!(parsed["lpcs_router_routed_total"] >= 1);
    assert_eq!(parsed["lpcs_router_inflight"], 0);
    for i in 0..2 {
        assert!(
            parsed.keys().any(|k| k.starts_with(&format!(
                "lpcs_router_backend_up{{backend=\"{i}\","
            ))),
            "backend {i} missing from the router exposition"
        );
    }
    // The router's own hop family, labeled by the backend the job was
    // forwarded to.
    assert!(
        parsed
            .keys()
            .any(|k| k.starts_with("lpcs_router_submit_forward_us_count{backend=\"")),
        "no per-backend submit-forward hop series in the federated scrape"
    );
    // The backends' solver families, merged into the same exposition.
    assert_eq!(
        parsed["lpcs_jobs_total{solver=\"niht\",engine=\"native-dense\",bits=\"32\",outcome=\"ok\"}"],
        1
    );
    assert!(
        parsed.keys().any(|k| k.starts_with("lpcs_job_e2e_us_count{")),
        "merged backend e2e family missing"
    );
    // Both backends were reachable: no scrape errors.
    assert_eq!(parsed["lpcs_backend_scrape_errors{backend=\"0\"}"], 0);
    assert_eq!(parsed["lpcs_backend_scrape_errors{backend=\"1\"}"], 0);
    // The merge preserves the structural invariants and the trace-id
    // exemplar the watcher saw rides the merged e2e family.
    assert_internally_consistent(&parsed);
    assert!(
        text.contains(&format!("trace_id=\"{trace:016x}\"")),
        "the watched job's trace id is not carried by any exemplar in:\n{text}"
    );

    // A backend scraped directly still serves the full solver view.
    let backend = parse(&h.backend_client(0).scrape().expect("scrape backend 0"));
    assert!(backend.contains_key("lpcs_workers_total"));
    h.shutdown();
}

#[test]
fn killing_a_backend_degrades_the_federated_scrape_to_an_error_counter() {
    // Round-robin placement so both backends hold terminal jobs before
    // one dies; the routed ids alternate 0,1 deterministically.
    let mut h = RouterHarness::start_with(
        2,
        ServiceConfig { workers: 1, queue_capacity: 8, max_batch: 2, ..Default::default() },
        SolveOptions::default(),
        |rcfg| rcfg.affinity = false,
    );
    for seed in [31u64, 32] {
        let mut client = h.client();
        let (phi, y) = planted(96, 192, 5, seed);
        let spec = JobSpec::builder(ProblemHandle::new(phi), y, 5)
            .solver(SolverKind::Niht)
            .engine(EngineKind::NativeDense)
            .seed(seed)
            .build();
        let id = client.submit(&spec).unwrap();
        for event in client.watch(id).unwrap() {
            if let WatchEvent::Done(out) = event.unwrap() {
                assert!(out.error.is_none(), "{:?}", out.error);
            }
        }
    }

    // Kill backend 1's network face; its service keeps running, exactly
    // like a machine partition. The very next scrape must not stall and
    // must stay internally consistent over the surviving backend.
    h.kill_backend_server(1);
    let mut client = h.client();
    let parsed = parse(&client.scrape().expect("scrape with a dead backend"));
    assert_internally_consistent(&parsed);
    assert_eq!(parsed["lpcs_backend_scrape_errors{backend=\"0\"}"], 0);
    let errs = parsed["lpcs_backend_scrape_errors{backend=\"1\"}"];
    assert!(errs >= 1, "dead backend must count a scrape error, got {errs}");
    // Only the surviving backend's jobs are visible in the merge.
    assert_eq!(
        parsed["lpcs_jobs_total{solver=\"niht\",engine=\"native-dense\",bits=\"32\",outcome=\"ok\"}"],
        1
    );
    // Errors are a monotone counter: the next scrape fails the same
    // backend again.
    let again = parse(&client.scrape().expect("second scrape with a dead backend"));
    assert!(
        again["lpcs_backend_scrape_errors{backend=\"1\"}"] > errs,
        "scrape-error counter must increment on every failed federation leg"
    );
    h.shutdown();
}
