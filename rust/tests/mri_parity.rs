//! MRI operator parity: the matrix-free FFT path against a densely
//! materialized DFT matrix — raw products and through the facade's
//! generic `OpKernel` NIHT driver — plus the FFT-vs-naive-DFT property
//! sweep at the integration level.

use lpcs::algorithms::NihtKernel;
use lpcs::fft;
use lpcs::linalg;
use lpcs::mri::{MaskConfig, MaskKind, PartialFourierOp, SamplingMask};
use lpcs::rng::XorShift128Plus;
use lpcs::solver::{MeasurementOp, OpKernel};

fn close(got: &[f32], want: &[f32], tol: f32, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!((g - w).abs() <= tol, "{ctx}[{i}]: {g} vs {w}");
    }
}

fn ops() -> Vec<(String, PartialFourierOp)> {
    let mut out = Vec::new();
    for r in [8usize, 16, 32] {
        for kind in [MaskKind::Cartesian, MaskKind::Radial] {
            let cfg = MaskConfig { kind, ..Default::default() };
            let mask = SamplingMask::generate(&cfg, r, 11).unwrap();
            out.push((format!("{} r={r}", kind.name()), PartialFourierOp::new(mask)));
        }
    }
    out
}

#[test]
fn apply_and_adjoint_match_the_materialized_dft_matrix() {
    let mut rng = XorShift128Plus::new(1);
    for (ctx, op) in ops() {
        let mat = op.to_mat();
        assert_eq!((mat.rows, mat.cols), (op.m(), op.n()), "{ctx}");
        // Unit-scale data (the phantom's range): tolerance 1e-5 absolute.
        let x: Vec<f32> = (0..op.n()).map(|_| rng.uniform_f32()).collect();
        close(&op.apply(&x), &mat.matvec(&x), 1e-5, &format!("{ctx} apply"));
        let v: Vec<f32> = (0..op.m()).map(|_| rng.uniform_f32() - 0.5).collect();
        close(&op.apply_t(&v), &mat.matvec_t(&v), 1e-5, &format!("{ctx} adjoint"));
        // Sparse apply (the line-search product) against the dense one.
        let idx: Vec<usize> = (0..op.n()).step_by(op.n() / 7).collect();
        let vals: Vec<f32> = idx.iter().map(|_| rng.uniform_f32()).collect();
        close(
            &op.apply_sparse(&idx, &vals),
            &mat.matvec_sparse(&idx, &vals),
            1e-5,
            &format!("{ctx} apply_sparse"),
        );
    }
}

#[test]
fn op_kernel_steps_match_through_the_facade_driver() {
    // One full NIHT step (gradient, adaptive μ, thresholded iterate)
    // computed by the SAME generic OpKernel over (a) the matrix-free
    // operator and (b) its materialization: ≤ 1e-5 throughout.
    let mut rng = XorShift128Plus::new(2);
    for (ctx, op) in ops() {
        let mat = op.to_mat();
        let mut x_true = vec![0.0f32; op.n()];
        for i in rng.choose_k(op.n(), 6) {
            x_true[i] = 0.5 + rng.uniform_f32();
        }
        let y = op.apply(&x_true);
        let y_mat = mat.matvec(&x_true);
        close(&y, &y_mat, 1e-5, &format!("{ctx} y"));

        let mut k_free = OpKernel::new(&op, &y);
        let mut k_dense = OpKernel::new(&mat, &y);
        let x0 = vec![0.0f32; op.n()];
        let st_free = k_free.full_step(&x0, 6);
        let st_dense = k_dense.full_step(&x0, 6);
        close(&st_free.g, &st_dense.g, 1e-5, &format!("{ctx} gradient"));
        assert!(
            (st_free.mu - st_dense.mu).abs() <= 1e-4 * (1.0 + st_dense.mu.abs()),
            "{ctx} mu: {} vs {}",
            st_free.mu,
            st_dense.mu
        );
        close(&st_free.x_next, &st_dense.x_next, 1e-4, &format!("{ctx} x_next"));
    }
}

#[test]
fn short_trajectories_track_between_matrix_free_and_dense() {
    // A few full driver iterations end-to-end: supports match and the
    // iterates stay within loose f32-drift tolerance (discrete support
    // selection amplifies ulp differences, so this is deliberately not a
    // bit-equality test).
    use lpcs::algorithms::SolveOptions;
    use lpcs::solver::{Problem, Recovery, SolverKind};
    use std::sync::Arc;

    let mask = SamplingMask::generate(&MaskConfig::default(), 16, 5).unwrap();
    let op = Arc::new(PartialFourierOp::new(mask));
    let mat = Arc::new(op.to_mat());
    let mut x_true = vec![0.0f32; 256];
    let mut rng = XorShift128Plus::new(3);
    for i in rng.choose_k(256, 8) {
        x_true[i] = 1.0 + rng.uniform_f32();
    }
    let y = op.apply(&x_true);
    let opts = SolveOptions::default().with_max_iters(6).with_tol(0.0);
    let free = Recovery::problem(Problem::with_op(op, y.clone(), 8))
        .solver(SolverKind::Niht)
        .options(opts.clone())
        .run()
        .unwrap();
    let dense = Recovery::problem(Problem::new(mat, y, 8))
        .solver(SolverKind::Niht)
        .options(opts)
        .run()
        .unwrap();
    assert_eq!(free.iterations, dense.iterations);
    let diff = linalg::norm2(&linalg::sub(&free.x, &dense.x));
    let norm = linalg::norm2(&dense.x);
    assert!(diff <= 1e-3 * norm.max(1.0), "trajectory drift {diff} vs norm {norm}");
}

#[test]
fn fft_property_sweep_against_naive_dft() {
    // Integration-level restatement of the unit sweep: every power of two
    // in 2..=1024, forward and inverse, relative L2 ≤ 1e-5.
    let mut rng = XorShift128Plus::new(4);
    let mut n = 2usize;
    while n <= 1024 {
        let re0 = rng.gaussian_vec(n);
        let im0 = rng.gaussian_vec(n);
        for inverse in [false, true] {
            let (want_re, want_im) = fft::dft_naive(&re0, &im0, inverse);
            let mut re = re0.clone();
            let mut im = im0.clone();
            fft::fft_inplace(&mut re, &mut im, inverse);
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for i in 0..n {
                num += ((re[i] - want_re[i]) as f64).powi(2)
                    + ((im[i] - want_im[i]) as f64).powi(2);
                den += (want_re[i] as f64).powi(2) + (want_im[i] as f64).powi(2);
            }
            let rel = (num / den.max(1e-30)).sqrt();
            assert!(rel <= 1e-5, "n={n} inverse={inverse}: rel {rel}");
        }
        n *= 2;
    }
}
