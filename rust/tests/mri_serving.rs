//! MRI serving conformance: matrix-free partial-Fourier jobs round-trip
//! the coordinator bit-for-bit against the facade, invalid mask
//! parameters die at submit (counted in `ServiceMetrics.invalid`), and
//! the acceptance pin: 8-bit quantized MRI recovery lands within 1 dB of
//! the f32 matrix-free baseline on the 64×64 phantom.

use lpcs::algorithms::SolveOptions;
use lpcs::config::{EngineKind, ServiceConfig};
use lpcs::coordinator::{JobSpec, JobState, ProblemHandle, RecoveryService};
use lpcs::metrics;
use lpcs::mri::{self, MaskConfig, MriConfig, MriProblem, PartialFourierOp, SamplingMask};
use lpcs::solver::{Problem, Recovery, SolverKind};
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

fn service(workers: usize) -> RecoveryService {
    RecoveryService::start(
        ServiceConfig { workers, queue_capacity: 64, max_batch: 4, ..Default::default() },
        SolveOptions::default(),
        PathBuf::from("artifacts"),
    )
}

fn problem(r: usize, seed: u64) -> MriProblem {
    let cfg = MriConfig { resolution: r, ..Default::default() };
    MriProblem::build(&cfg, seed).unwrap()
}

#[test]
fn matrix_free_mri_jobs_round_trip_the_serving_path_bit_identically() {
    let service = service(2);
    let p = problem(32, 3);
    // (bits, seed) cases: the f32 path and the quantized path at every
    // packed width — each served result must equal the facade's
    // `service_dispatch` run of the same spec bit-for-bit.
    for (case, bits) in [None, Some(8u8), Some(4), Some(2)].into_iter().enumerate() {
        let seed = 50 + case as u64;
        let direct_problem = match bits {
            None => Problem::with_op(p.op.clone(), p.y.clone(), p.s),
            Some(b) => mri::lowprec_problem(p.op.clone(), &p.y, p.s, b, seed),
        };
        let direct = Recovery::problem(direct_problem)
            .solver(SolverKind::Niht)
            .engine(EngineKind::NativeDense)
            .seed(seed)
            .service_dispatch()
            .run()
            .unwrap_or_else(|e| panic!("bits={bits:?}: direct run failed: {e:#}"));

        let handle = match bits {
            None => ProblemHandle::partial_fourier(p.op.clone()),
            Some(b) => ProblemHandle::low_prec_fourier(p.op.clone(), b),
        };
        let id = service
            .submit(
                JobSpec::builder(handle, p.y.clone(), p.s)
                    .engine(EngineKind::NativeDense)
                    .solver(SolverKind::Niht)
                    .seed(seed)
                    .build(),
            )
            .unwrap_or_else(|e| panic!("bits={bits:?}: submit failed: {e:#}"));
        let out = service.wait(id, Duration::from_secs(120)).expect("job finishes");
        assert_eq!(out.state, JobState::Done, "bits={bits:?}: {:?}", out.error);
        let served = out.result.unwrap();
        assert_eq!(served.x, direct.x, "bits={bits:?}: served x̂ ≠ facade x̂");
        assert_eq!(served.iterations, direct.iterations, "bits={bits:?}");
        assert_eq!(served.converged, direct.converged, "bits={bits:?}");
    }
    service.shutdown();
}

#[test]
fn shared_op_jobs_batch_and_all_recover() {
    // Several observations against ONE shared operator Arc — the MRI
    // stream analog of the telescope's shared-Φ snapshot stream. All
    // must complete through the scheduler/batcher with the operator as
    // the batch identity.
    let service = service(2);
    let p = problem(16, 4);
    let mut ids = Vec::new();
    for k in 0..6u64 {
        let handle = if k % 2 == 0 {
            ProblemHandle::partial_fourier(p.op.clone())
        } else {
            ProblemHandle::low_prec_fourier(p.op.clone(), 8)
        };
        let id = service
            .submit(
                JobSpec::builder(handle, p.y.clone(), p.s)
                    .engine(EngineKind::NativeDense)
                    .solver(SolverKind::Niht)
                    .seed(k)
                    .build(),
            )
            .unwrap();
        ids.push(id);
    }
    for id in ids {
        let out = service.wait(id, Duration::from_secs(120)).expect("finishes");
        assert_eq!(out.state, JobState::Done, "{:?}", out.error);
        let x = out.result.unwrap().x;
        // All jobs share y here, so every recovery resembles the truth
        // (reference sim puts this scale at ~16.5 dB; the bound is a
        // loose regression floor, not a quality claim).
        assert!(
            metrics::psnr(&x, &p.x_true) > 12.0,
            "served reconstruction quality: {:.2} dB",
            metrics::psnr(&x, &p.x_true)
        );
    }
    assert_eq!(service.metrics().completed.load(Ordering::Relaxed), 6);
    service.shutdown();
}

#[test]
fn invalid_mask_parameters_rejected_at_submit_and_counted() {
    let service = service(1);
    // Build operators around degenerate masks (generation is total; the
    // parameter gate lives in validation) and around a bad bit width.
    let bad_fraction = SamplingMask::generate(
        &MaskConfig { fraction: 0.0, ..Default::default() },
        16,
        0,
    )
    .unwrap();
    let op_bad = Arc::new(PartialFourierOp::new(bad_fraction));
    let m = ProblemHandle::partial_fourier(op_bad.clone()).m();
    let err = service
        .submit(
            JobSpec::builder(ProblemHandle::partial_fourier(op_bad), vec![0.0; m], 4)
                .engine(EngineKind::NativeDense)
                .solver(SolverKind::Niht)
                .build(),
        )
        .unwrap_err()
        .to_string();
    assert!(err.contains("invalid job spec"), "{err}");

    let zero_band = SamplingMask::generate(
        &MaskConfig { center_band: 0, fraction: 0.25, ..Default::default() },
        16,
        0,
    )
    .unwrap();
    let op_band = Arc::new(PartialFourierOp::new(zero_band));
    let m = ProblemHandle::partial_fourier(op_band.clone()).m();
    assert!(service
        .submit(
            JobSpec::builder(ProblemHandle::partial_fourier(op_band), vec![0.0; m], 4)
                .engine(EngineKind::NativeDense)
                .solver(SolverKind::Niht)
                .build(),
        )
        .is_err());

    // Solver/engine surface violations for matrix-free jobs.
    let good = SamplingMask::generate(&MaskConfig::default(), 16, 1).unwrap();
    let op = Arc::new(PartialFourierOp::new(good));
    let m = ProblemHandle::partial_fourier(op.clone()).m();
    assert!(service
        .submit(
            JobSpec::builder(ProblemHandle::partial_fourier(op.clone()), vec![0.0; m], 4)
                .engine(EngineKind::NativeDense)
                .solver(SolverKind::Cosamp)
                .build(),
        )
        .is_err());
    assert!(service
        .submit(
            JobSpec::builder(ProblemHandle::low_prec_fourier(op, 8), vec![0.0; m], 4)
                .engine(EngineKind::NativeQuant)
                .solver(SolverKind::Niht)
                .build(),
        )
        .is_err());

    let metrics = service.metrics();
    assert_eq!(metrics.invalid.load(Ordering::Relaxed), 4, "all four counted invalid");
    assert_eq!(metrics.submitted.load(Ordering::Relaxed), 0, "no job id allocated");
    service.shutdown();
}

#[test]
fn eight_bit_recovery_within_one_db_of_f32_on_the_64x64_phantom() {
    // The acceptance pin. Configuration chosen (and validated against a
    // reference simulation) so the f32 baseline sits in the regime where
    // quantization noise stays below reconstruction error: 64×64,
    // variable-density Cartesian at fraction 0.35, centre band 4,
    // s = n/10.
    let cfg = MriConfig {
        resolution: 64,
        mask: MaskConfig { fraction: 0.35, center_band: 4, ..Default::default() },
        sparsity: 64 * 64 / 10,
        ..Default::default()
    };
    let p = MriProblem::build(&cfg, 1).unwrap();

    let f32_rep = Recovery::problem(Problem::with_op(p.op.clone(), p.y.clone(), p.s))
        .solver(SolverKind::Niht)
        .run()
        .unwrap();
    let psnr_f32 = metrics::psnr(&f32_rep.x, &p.x_true);

    let q8_rep = Recovery::problem(mri::lowprec_problem(p.op.clone(), &p.y, p.s, 8, 1))
        .solver(SolverKind::Niht)
        .seed(1)
        .run()
        .unwrap();
    let psnr_q8 = metrics::psnr(&q8_rep.x, &p.x_true);

    assert!(
        psnr_f32 > 18.0,
        "f32 baseline must reconstruct the phantom at all: {psnr_f32:.2} dB"
    );
    assert!(
        psnr_q8 >= psnr_f32 - 1.0,
        "8-bit sampling path within 1 dB of f32: {psnr_q8:.2} vs {psnr_f32:.2} dB"
    );
}
