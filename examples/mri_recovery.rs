//! End-to-end MRI demo (the paper's second application, §10):
//!
//!   Shepp–Logan phantom → s-sparse recovery target → variable-density
//!   k-space undersampling → matrix-free partial-Fourier NIHT (f32) →
//!   the b-bit low-precision sampling path → PSNR + PGM panels.
//!
//! Usage (both arguments optional):
//!
//!   cargo run --release --example mri_recovery -- [resolution] [bits]
//!
//! `resolution` must be a power of two ≥ 8 (default 64); `bits` ∈
//! {2, 4, 8} selects the quantized path, 0 skips it (default 8). CI
//! smoke-runs `-- 32 8`. Panels land in `results/mri/`.

use lpcs::metrics;
use lpcs::mri::{self, MriConfig, MriProblem};
use lpcs::solver::{Problem, Recovery, SolverKind};
use lpcs::{io::pgm, SolveReport};
use std::path::Path;
use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let resolution: usize = args.first().and_then(|a| a.parse().ok()).unwrap_or(64);
    let bits: u8 = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(8);
    let seed = 7u64;

    let cfg = MriConfig { resolution, bits, ..Default::default() };
    let t0 = Instant::now();
    let p = MriProblem::build(&cfg, seed).expect("valid MRI config");
    let mask = p.op.mask();
    println!(
        "phantom {r}x{r} (N={n}), {kind} mask: {k} of {n} k-space samples ({us:.1}%), \
         M={m} stacked-real rows, s={s}  [built in {dt:.2?}]",
        r = p.r,
        n = p.n(),
        kind = mask.config().kind.name(),
        k = mask.len(),
        us = 100.0 * mask.undersampling(),
        m = p.m(),
        s = p.s,
        dt = t0.elapsed(),
    );

    let out = Path::new("results/mri");
    let range = Some((0.0f32, p.x_true.iter().cloned().fold(0.0, f32::max)));
    pgm::write_pgm(&out.join("truth.pgm"), &p.x_true, p.r, p.r, range).expect("write");

    // The classical zero-filled estimate Φᵀy — what you get without CS.
    let zf = p.op.zero_filled(&p.y);
    println!(
        "zero-filled Φᵀy       psnr={:>6.2} dB   (aliased classical baseline)",
        metrics::psnr(&zf, &p.x_true)
    );
    pgm::write_pgm(&out.join("zero_filled.pgm"), &zf, p.r, p.r, range).expect("write");

    let report = |tag: &str, rep: &SolveReport| {
        println!(
            "{tag:<22}psnr={:>6.2} dB   {} iters in {:.3?} ({})",
            metrics::psnr(&rep.x, &p.x_true),
            rep.iterations,
            rep.wall,
            rep.engine,
        );
    };

    // f32 matrix-free recovery: Problem::with_op — no matrix anywhere.
    let f32_rep = Recovery::problem(Problem::with_op(p.op.clone(), p.y.clone(), p.s))
        .solver(SolverKind::Niht)
        .run()
        .expect("f32 solve");
    report("NIHT matrix-free f32", &f32_rep);
    pgm::write_pgm(&out.join("recon_f32.pgm"), &f32_rep.x, p.r, p.r, range).expect("write");

    if bits != 0 {
        // The low-precision sampling path: ŷ and per-iteration k-space
        // traffic stochastically quantized at per-readout block scales.
        let q_rep = Recovery::problem(mri::lowprec_problem(p.op.clone(), &p.y, p.s, bits, seed))
            .solver(SolverKind::Niht)
            .seed(seed)
            .run()
            .expect("quantized solve");
        report(&format!("NIHT {bits}-bit sampling"), &q_rep);
        pgm::write_pgm(&out.join(format!("recon_q{bits}.pgm")), &q_rep.x, p.r, p.r, range)
            .expect("write");

        let delta = metrics::psnr(&q_rep.x, &p.x_true) - metrics::psnr(&f32_rep.x, &p.x_true);
        println!("Δ(q{bits} − f32) = {delta:+.2} dB");
    }
    println!("PGM panels written to {out:?}");
}
