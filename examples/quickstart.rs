//! Quickstart: recover a sparse signal from 2.7× undersampled measurements
//! with the measurement data quantized to 2 bits (matrix) and 8 bits
//! (observations) — the paper's headline configuration — through the
//! unified `solver` facade.
//!
//! Run: `cargo run --release --example quickstart`

use lpcs::linalg::Mat;
use lpcs::metrics;
use lpcs::rng::XorShift128Plus;
use lpcs::solver::{Problem, Recovery, SolverKind};

fn main() {
    // 1. A compressive-sensing problem: y = Φx + e with x s-sparse.
    let (m, n, s) = (192usize, 512usize, 8usize);
    let mut rng = XorShift128Plus::new(42);
    let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
    let mut x_true = vec![0.0f32; n];
    for i in rng.choose_k(n, s) {
        x_true[i] = 2.0 * rng.gaussian_f32().signum() + 0.3 * rng.gaussian_f32();
    }
    let y = phi.matvec(&x_true);
    println!("problem: Φ ∈ R^{{{m}×{n}}}, ‖x‖₀ = {s}, noiseless");

    // 2. The whole recovery API is three lines: wrap the problem, pick a
    //    solver, run. Engine, options, seed and observer are optional —
    //    each solver defaults to its natural engine.
    let problem = Problem::from_mat(phi, y, s);
    let dense = Recovery::problem(problem.clone()).solver(SolverKind::Niht).run().unwrap();
    println!(
        "32-bit NIHT:     {} iterations on {}, recovery error {:.2e}, support {:.0}%",
        dense.iterations,
        dense.engine,
        metrics::recovery_error(&dense.x, &x_true),
        100.0 * metrics::exact_recovery(&dense.x, &x_true)
    );

    // 3. Low-precision QNIHT: Φ at 2 bits, y at 8 bits, fresh stochastic
    //    quantizations per iteration (Algorithm 1 / Theorem 3). Cloning a
    //    Problem is cheap — Φ lives behind an Arc.
    let quant = Recovery::problem(problem)
        .solver(SolverKind::qniht_fresh(2, 8))
        .seed(7)
        .run()
        .unwrap();
    println!(
        "2&8-bit QNIHT:   {} iterations on {}, recovery error {:.2e}, support {:.0}%",
        quant.iterations,
        quant.engine,
        metrics::recovery_error(&quant.x, &x_true),
        100.0 * metrics::exact_recovery(&quant.x, &x_true)
    );

    // 4. The systems payoff: Φ̂ moves 16× fewer bytes per iteration.
    println!(
        "traffic per iteration: f32 = {} KiB, 2-bit = {} KiB (16× less)",
        m * n * 4 / 1024,
        m * n * 2 / 8 / 1024
    );
}
