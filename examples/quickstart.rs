//! Quickstart: recover a sparse signal from 2.7× undersampled measurements
//! with the measurement data quantized to 2 bits (matrix) and 8 bits
//! (observations) — the paper's headline configuration.
//!
//! Run: `cargo run --release --example quickstart`

use lpcs::algorithms::niht::niht_dense;
use lpcs::algorithms::qniht::{qniht, RequantMode};
use lpcs::algorithms::SolveOptions;
use lpcs::linalg::Mat;
use lpcs::metrics;
use lpcs::rng::XorShift128Plus;

fn main() {
    // 1. A compressive-sensing problem: y = Φx + e with x s-sparse.
    let (m, n, s) = (192usize, 512usize, 8usize);
    let mut rng = XorShift128Plus::new(42);
    let phi = Mat::from_fn(m, n, |_, _| rng.gaussian_f32() / (m as f32).sqrt());
    let mut x_true = vec![0.0f32; n];
    for i in rng.choose_k(n, s) {
        x_true[i] = 2.0 * rng.gaussian_f32().signum() + 0.3 * rng.gaussian_f32();
    }
    let y = phi.matvec(&x_true);
    println!("problem: Φ ∈ R^{{{m}×{n}}}, ‖x‖₀ = {s}, noiseless");

    // 2. Full-precision NIHT (the 32-bit baseline).
    let opts = SolveOptions::default();
    let dense = niht_dense(&phi, &y, s, &opts);
    println!(
        "32-bit NIHT:     {} iterations, recovery error {:.2e}, support {:.0}%",
        dense.iterations,
        metrics::recovery_error(&dense.x, &x_true),
        100.0 * metrics::exact_recovery(&dense.x, &x_true)
    );

    // 3. Low-precision QNIHT: Φ at 2 bits, y at 8 bits. Fresh stochastic
    //    quantizations per iteration (Algorithm 1 / Theorem 3).
    let quant = qniht(&phi, &y, s, 2, 8, RequantMode::Fresh, 7, &opts);
    println!(
        "2&8-bit QNIHT:   {} iterations, recovery error {:.2e}, support {:.0}%",
        quant.iterations,
        metrics::recovery_error(&quant.x, &x_true),
        100.0 * metrics::exact_recovery(&quant.x, &x_true)
    );

    // 4. The systems payoff: Φ̂ moves 16× fewer bytes per iteration.
    println!(
        "traffic per iteration: f32 = {} KiB, 2-bit = {} KiB (16× less)",
        m * n * 4 / 1024,
        m * n * 2 / 8 / 1024
    );
}
