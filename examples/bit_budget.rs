//! Bit-budget planner: given a telescope configuration, compute the RIP
//! diagnostics (γ, α, β over random supports) and the Lemma-1 minimum bit
//! width, plus the Theorem-3 / Corollary-1 error forecast per precision —
//! the workflow §3.2 and §7.3 of the paper describe for instrument design.
//! Ends with a facade-driven recovery at the planned precision to confirm
//! the budget empirically.
//!
//! Run: `cargo run --release --example bit_budget`

use lpcs::linalg::norm2;
use lpcs::metrics;
use lpcs::rip;
use lpcs::rng::XorShift128Plus;
use lpcs::solver::{Problem, Recovery, SolverKind};
use lpcs::telescope::{steering, AntennaArray, ImageGrid, SkyModel};

fn main() {
    let (l, r, s) = (12usize, 24usize, 6usize);
    println!("planning for L={l} antennas, {r}×{r} grid, s={s} sources\n");

    let mut rng = XorShift128Plus::new(3);
    let array = AntennaArray::lofar_like(l, 50e6, &mut rng);

    println!("{:<8} {:>10} {:>10} {:>10} {:>9} {:>9}", "d", "gamma_2s", "alpha_2s", "beta_2s", "minbits", "eps_q@2b");
    for d in [0.2f64, 0.4, 0.6, 0.8] {
        let grid = ImageGrid::new(r, d);
        let phi = steering::stacked_measurement_matrix_unique(&array, &grid);
        let est = rip::ric_probe(&phi, 2 * s, 6, 17);
        let bits = rip::min_bits_for_matrix(est.gamma(), est.alpha as f64, 2 * s);
        // Error forecast for a typical sky.
        let sky = SkyModel::random_points(&grid, s, &mut rng);
        let xs = sky.to_vector(grid.pixels());
        let eq2 = rip::epsilon_q(phi.rows, est.beta as f64, norm2(&xs) as f64, 2, 8);
        println!(
            "{d:<8} {:>10.4} {:>10.3} {:>10.3} {:>9} {:>9.4}",
            est.gamma(),
            est.alpha,
            est.beta,
            bits.map(|b| b.to_string()).unwrap_or_else(|| "-".into()),
            eq2
        );
    }

    println!(
        "\nLemma 1: b ≥ log2(2√|Γ| / (ε·α)); '-' = γ > 1/16, quantization\n\
         guarantees unavailable (recovery may still work in practice).\n\
         ε_q@2b: Theorem 3's additive error for 2-bit Φ / 8-bit y."
    );

    // Empirical check of the plan: recover a synthetic sky at the planned
    // 2-bit precision through the solver facade.
    let grid = ImageGrid::new(r, 0.4);
    let phi = steering::stacked_measurement_matrix_unique(&array, &grid);
    let sky = SkyModel::random_points(&grid, s, &mut rng);
    let xs = sky.to_vector(grid.pixels());
    let y = phi.matvec(&xs);
    let report = Recovery::problem(Problem::from_mat(phi, y, s))
        .solver(SolverKind::qniht_fresh(2, 8))
        .seed(3)
        .run()
        .expect("recovery");
    println!(
        "\nempirical check (d=0.4, 2&8-bit QNIHT): {} iterations, recovery error {:.3}",
        report.iterations,
        metrics::recovery_error(&report.x, &xs)
    );
}
