//! End-to-end driver (DESIGN.md E1 + EXPERIMENTS.md §End-to-end): the full
//! radio-astronomy pipeline on a realistic workload —
//!
//!   LOFAR-like station geometry → measurement matrix Φ (Eqn. 75) →
//!   synthetic sky (30 sources) → visibilities at 0 dB SNR → dirty image →
//!   32-bit NIHT vs 2&8-bit QNIHT (native + PJRT/XLA engines) → metrics.
//!
//! Every solve goes through the unified `solver` facade; switching from
//! the native engines to the PJRT/XLA artifact engine is one `.engine()`
//! call — the registry owns dispatch, runtime creation and executable
//! caching. Run after `make artifacts`:
//!
//!   cargo run --release --example sky_recovery

use lpcs::config::EngineKind;
use lpcs::metrics;
use lpcs::solver::{Problem, Recovery, SolveReport, SolverKind};
use lpcs::telescope::{dirty, AstroConfig, AstroProblem};
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    // The astro AOT artifact shape: L=10 ⇒ 2L² = 200 stacked rows, r=32 ⇒
    // N=1024, s=16 (paper scale is L=30/r=256; shape-independent, see
    // DESIGN.md §6.2).
    let cfg = AstroConfig {
        antennas: 10,
        resolution: 32,
        sources: 16,
        // Paper scale is L=30 (900 baselines) at 0 dB; a 10-antenna
        // station has 9x fewer baselines to average the noise over, so the
        // equivalent operating point is ~10 dB (noise-per-source matched).
        snr_db: 10.0,
        ..Default::default()
    };
    let s = cfg.sources;
    let r = cfg.resolution;
    let t0 = Instant::now();
    let p = AstroProblem::build(&cfg, 11);
    println!(
        "station: L={} antennas, grid {r}×{r} (N={}), M={} stacked-real rows, {} sources, {} dB SNR  [built in {:.2?}]",
        cfg.antennas, p.n(), p.m(), s, cfg.snr_db, t0.elapsed()
    );

    let report = |name: &str, x: &[f32], t: std::time::Duration, iters: usize| {
        println!(
            "{name:<22} {iters:>4} iters  {t:>9.3?}  err={:.4}  support={:>5.1}%  sources resolved {}/{}",
            metrics::recovery_error(x, &p.x_true),
            100.0 * metrics::exact_recovery_top_s(x, &p.x_true),
            metrics::sources_resolved(x, &p.sky.sources, r, 1, 0.4),
            s
        );
    };
    let report_solve = |name: &str, rep: &SolveReport| {
        report(name, &rep.x, rep.wall, rep.iterations);
    };

    // Dirty image (the classical least-squares estimate).
    let t = Instant::now();
    let dimg = dirty::dirty_image(&p.phi, &p.y);
    report("dirty image", &dimg, t.elapsed(), 1);

    // One shared Problem (Φ behind an Arc, tagged with the artifact shape
    // so the XLA engine can find its AOT executables).
    let problem = Problem::new(Arc::new(p.phi.clone()), p.y.clone(), s)
        .with_shape_tag("astro_200x1024");

    let d = Recovery::problem(problem.clone())
        .solver(SolverKind::Niht)
        .run()
        .expect("dense solve");
    report_solve("NIHT 32-bit (native)", &d);

    let q = Recovery::problem(problem.clone())
        .solver(SolverKind::qniht_fixed(2, 8))
        .seed(3)
        .run()
        .expect("quant solve");
    report_solve("QNIHT 2&8 (native)", &q);

    // The PJRT path: every step executes the AOT-compiled JAX graph with
    // the Pallas dequantize-matvec kernels — same builder, different
    // engine.
    let artifacts = Path::new("artifacts");
    if artifacts.join("manifest.json").exists() {
        match Recovery::problem(problem)
            .solver(SolverKind::qniht_fixed(2, 8))
            .engine(EngineKind::XlaQuant)
            .artifact_dir(artifacts)
            .seed(3)
            .run()
        {
            Ok(xq) => report_solve("QNIHT 2&8 (XLA/PJRT)", &xq),
            Err(e) => println!("XLA engine unavailable: {e:#}"),
        }
    } else {
        println!("(run `make artifacts` to also exercise the XLA/PJRT engine)");
    }

    println!("total {:.2?}", t0.elapsed());
}
