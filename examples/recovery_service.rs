//! Serving example: the L3 recovery service under a bursty stream of
//! visibility snapshots that share one measurement matrix. Reports
//! throughput, latency percentiles, batching efficiency (the engine
//! registry quantizes+packs Φ once per batch; the cost-aware scheduler
//! regroups interleaved precisions into amortizable batches),
//! backpressure behaviour, the per-job progress/cancellation API, and
//! the fpga-model engine answering "what would this snapshot cost on the
//! FPGA at 2/4/8 bits?".
//!
//! Run: `cargo run --release --example recovery_service`

use lpcs::algorithms::SolveOptions;
use lpcs::config::{EngineKind, ServiceConfig};
use lpcs::coordinator::{JobSpec, ProblemHandle, RecoveryService};
use lpcs::metrics;
use lpcs::rng::XorShift128Plus;
use lpcs::solver::{Problem, Recovery, SolverKind};
use lpcs::telescope::{AstroConfig, AstroProblem};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let cfg = AstroConfig {
        antennas: 8,
        resolution: 24,
        sources: 8,
        snr_db: 10.0,
        ..Default::default()
    };
    let base = AstroProblem::build(&cfg, 5);
    let phi = Arc::new(base.phi.clone());
    let s = cfg.sources;

    let service = RecoveryService::start(
        ServiceConfig {
            workers: 4,
            queue_capacity: 64,
            max_batch: 8,
            max_wait_ms: 1,
            ..Default::default()
        },
        SolveOptions::default(),
        "artifacts".into(),
    );
    println!("service up: 4 workers, queue 64, max_batch 8, cost-aware scheduling");

    // A stream of snapshots: same Φ, fresh skies. Most run the paper's
    // 2&8-bit QNIHT on the native quantized engine; every sixth job asks
    // the fpga-model engine instead (same math, modeled clock) — the
    // scheduler regroups the interleaved engines into amortizable
    // batches, and the modeled device time lands in `modeled_ms=` below.
    let jobs = 48;
    let mut rng = XorShift128Plus::new(77);
    let t0 = Instant::now();
    let mut submitted = Vec::new();
    let mut skies = std::collections::HashMap::new();
    let mut rejected = 0usize;
    for j in 0..jobs {
        let mut x = vec![0.0f32; base.phi.cols];
        for i in rng.choose_k(base.phi.cols, s) {
            x[i] = 0.5 + rng.uniform_f32();
        }
        let y = base.phi.matvec(&x);
        let engine =
            if j % 6 == 5 { EngineKind::FpgaModel } else { EngineKind::NativeQuant };
        let spec = JobSpec::builder(ProblemHandle::new(phi.clone()), y, s)
            .bits(2, 8)
            .engine(engine)
            .seed(j as u64)
            .build();
        match service.submit(spec) {
            Ok(id) => {
                submitted.push(id);
                skies.insert(id, x);
            }
            Err(_) => rejected += 1,
        }
    }

    // The observer plumbing at work: poll one job's live progress, and
    // cancel the last submitted job (it completes with whatever iterate
    // it had — counted under `cancelled=` in the metrics below).
    if let Some(&probe) = submitted.first() {
        if let Some(stat) = service.progress(probe) {
            println!(
                "job {probe} live progress: iteration {} resid²={:.3e} μ={:.3}",
                stat.iter, stat.resid_nsq, stat.mu
            );
        }
    }
    if let Some(&victim) = submitted.last() {
        if service.cancel(victim) {
            println!("job {victim}: cancellation requested");
        }
    }

    let mut latencies = Vec::new();
    let mut resolved_total = 0usize;
    for id in &submitted {
        let out = service.wait(*id, Duration::from_secs(300)).expect("job finished");
        latencies.push(out.queued_for + out.ran_for);
        if let Some(res) = out.result {
            resolved_total +=
                metrics::sources_resolved(&res.x, &to_sources(&skies[id]), cfg.resolution, 1, 0.4);
        }
    }
    let wall = t0.elapsed();
    latencies.sort();

    println!(
        "{} jobs done ({} rejected by backpressure) in {:.2?} — {:.1} jobs/s",
        submitted.len(),
        rejected,
        wall,
        submitted.len() as f64 / wall.as_secs_f64()
    );
    println!(
        "latency p50={:.2?} p90={:.2?} p99={:.2?}",
        latencies[latencies.len() / 2],
        latencies[latencies.len() * 9 / 10],
        latencies[latencies.len() * 99 / 100]
    );
    println!(
        "sources resolved: {}/{} across all snapshots",
        resolved_total,
        submitted.len() * s
    );
    println!("service metrics: {}", service.metrics().snapshot());
    service.shutdown();

    // The FPGA bit-budget query, as a facade one-liner per precision:
    // the fpga-model engine runs the real quantized solve and bills
    // iterations × the §8 bandwidth model's iteration time.
    println!("\nFPGA cost query (one snapshot, modeled device time):");
    let mut x = vec![0.0f32; base.phi.cols];
    for i in XorShift128Plus::new(5).choose_k(base.phi.cols, s) {
        x[i] = 1.0;
    }
    let y = base.phi.matvec(&x);
    for bits in [2u8, 4, 8] {
        let report = Recovery::problem(Problem::new(phi.clone(), y.clone(), s))
            .solver(SolverKind::qniht_fixed(bits, 8))
            .engine(EngineKind::FpgaModel)
            .seed(5)
            .run()
            .expect("fpga-model solve");
        println!(
            "  {bits}&8-bit: {:>4} iterations -> modeled {:>9.3?}  (host wall {:.3?})",
            report.iterations,
            report.modeled.unwrap_or_default(),
            report.wall
        );
    }
}

fn to_sources(x: &[f32]) -> Vec<(usize, f32)> {
    x.iter().enumerate().filter(|(_, &v)| v != 0.0).map(|(i, &v)| (i, v)).collect()
}
